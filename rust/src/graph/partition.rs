//! Node partitioning and neighbour sampling for the multi-worker
//! (multi-GPU) mini-batch training simulation (paper §4.2, Fig. 9).
//!
//! The paper "directly adopts DGL's mini-batch multi-GPU training": each GPU
//! trains on a batch of sampled subgraphs per epoch, then gradients are
//! all-reduced. We reproduce the data path: a seeded node partitioner plus a
//! 1-hop fanout sampler that extracts per-worker subgraphs with local ids.

use super::Coo;
use crate::quant::rng::Xoshiro256pp;

/// A sampled subgraph with local node ids and the mapping back to the
/// parent graph.
#[derive(Debug, Clone)]
pub struct Subgraph {
    /// The local graph (nodes renumbered 0..n_local).
    pub graph: Coo,
    /// `local id -> parent id` for nodes.
    pub node_map: Vec<u32>,
    /// The seed (training) nodes, as local ids.
    pub seeds: Vec<u32>,
}

/// Split `nodes` into `k` near-equal shards after a seeded shuffle.
pub fn partition_nodes(nodes: &[u32], k: usize, seed: u64) -> Vec<Vec<u32>> {
    assert!(k >= 1);
    let mut order = nodes.to_vec();
    let mut rng = Xoshiro256pp::new(seed);
    for i in (1..order.len()).rev() {
        let j = (rng.next_u64() % (i as u64 + 1)) as usize;
        order.swap(i, j);
    }
    let mut shards: Vec<Vec<u32>> = vec![Vec::new(); k];
    for (i, v) in order.into_iter().enumerate() {
        shards[i % k].push(v);
    }
    shards
}

/// Sample a 1-hop subgraph around `seeds`: up to `fanout` in-edges per seed.
///
/// Mirrors DGL's `sample_neighbors` + `to_block` shape: the resulting local
/// graph contains the seeds plus their sampled frontier, with every sampled
/// edge pointing frontier→seed.
pub fn sample_subgraph(_parent: &Coo, in_csr: &super::Csr, seeds: &[u32], fanout: usize, seed: u64) -> Subgraph {
    let mut rng = Xoshiro256pp::new(seed);
    let mut local_of = std::collections::HashMap::new();
    let mut node_map = Vec::new();
    let local = |v: u32, node_map: &mut Vec<u32>, local_of: &mut std::collections::HashMap<u32, u32>| {
        *local_of.entry(v).or_insert_with(|| {
            node_map.push(v);
            (node_map.len() - 1) as u32
        })
    };
    let mut src = Vec::new();
    let mut dst = Vec::new();
    let local_seeds: Vec<u32> =
        seeds.iter().map(|&s| local(s, &mut node_map, &mut local_of)).collect();
    for &s in seeds {
        let (nbrs, _eids) = in_csr.row(s as usize);
        let take = fanout.min(nbrs.len());
        // Reservoir-free sampling: shuffle a candidate index window.
        let mut idx: Vec<usize> = (0..nbrs.len()).collect();
        for i in (1..idx.len()).rev() {
            let j = (rng.next_u64() % (i as u64 + 1)) as usize;
            idx.swap(i, j);
        }
        for &k in idx.iter().take(take) {
            let u = nbrs[k];
            let lu = local(u, &mut node_map, &mut local_of);
            let ls = local_of[&s];
            src.push(lu);
            dst.push(ls);
        }
    }
    let n_local = node_map.len();
    Subgraph { graph: Coo::new(n_local, src, dst), node_map, seeds: local_seeds }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;

    #[test]
    fn partition_covers_all_nodes_disjointly() {
        let nodes: Vec<u32> = (0..103).collect();
        let shards = partition_nodes(&nodes, 4, 9);
        assert_eq!(shards.len(), 4);
        let mut all: Vec<u32> = shards.concat();
        all.sort_unstable();
        assert_eq!(all, nodes);
        // near-equal sizes
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1, "{sizes:?}");
    }

    #[test]
    fn sample_respects_fanout() {
        let g = crate::graph::generators::erdos_renyi(100, 1000, 3);
        let csr = Csr::from_coo(&g);
        let seeds: Vec<u32> = (0..10).collect();
        let sub = sample_subgraph(&g, &csr, &seeds, 3, 7);
        // every seed pulls at most 3 in-edges
        let mut per_seed = std::collections::HashMap::new();
        for e in 0..sub.graph.num_edges() {
            *per_seed.entry(sub.graph.dst[e]).or_insert(0usize) += 1;
        }
        assert!(per_seed.values().all(|&c| c <= 3));
        assert_eq!(sub.seeds.len(), 10);
    }

    #[test]
    fn sampled_edges_exist_in_parent() {
        let g = crate::graph::generators::erdos_renyi(50, 300, 5);
        let csr = Csr::from_coo(&g);
        let seeds: Vec<u32> = vec![1, 2, 3];
        let sub = sample_subgraph(&g, &csr, &seeds, 5, 11);
        let parent_edges: std::collections::HashSet<(u32, u32)> =
            (0..g.num_edges()).map(|e| (g.src[e], g.dst[e])).collect();
        for e in 0..sub.graph.num_edges() {
            let ps = sub.node_map[sub.graph.src[e] as usize];
            let pd = sub.node_map[sub.graph.dst[e] as usize];
            assert!(parent_edges.contains(&(ps, pd)), "({ps},{pd}) not in parent");
        }
    }

    #[test]
    fn node_map_is_injective() {
        let g = crate::graph::generators::erdos_renyi(60, 400, 6);
        let csr = Csr::from_coo(&g);
        let sub = sample_subgraph(&g, &csr, &[0, 5, 9], 4, 1);
        let set: std::collections::HashSet<_> = sub.node_map.iter().collect();
        assert_eq!(set.len(), sub.node_map.len());
    }
}
