//! Node partitioning for the multi-worker (multi-GPU) mini-batch training
//! simulation (paper §4.2, Fig. 9).
//!
//! The paper "directly adopts DGL's mini-batch multi-GPU training": each GPU
//! owns a shard of the training nodes and sweeps it in sampled mini-batches.
//! This module provides the seeded partitioner; the sampling itself is the
//! layered [`crate::sampler::NeighborSampler`] (the ad-hoc 1-hop
//! `sample_subgraph` that used to live here is gone — the simulator consumes
//! [`crate::sampler::Block`]s like every other sampled-training consumer).

use crate::quant::rng::Xoshiro256pp;

/// Split `nodes` into `k` near-equal shards after a seeded shuffle.
pub fn partition_nodes(nodes: &[u32], k: usize, seed: u64) -> Vec<Vec<u32>> {
    assert!(k >= 1);
    let mut order = nodes.to_vec();
    let mut rng = Xoshiro256pp::new(seed);
    for i in (1..order.len()).rev() {
        let j = (rng.next_u64() % (i as u64 + 1)) as usize;
        order.swap(i, j);
    }
    let mut shards: Vec<Vec<u32>> = vec![Vec::new(); k];
    for (i, v) in order.into_iter().enumerate() {
        shards[i % k].push(v);
    }
    shards
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_all_nodes_disjointly() {
        let nodes: Vec<u32> = (0..103).collect();
        let shards = partition_nodes(&nodes, 4, 9);
        assert_eq!(shards.len(), 4);
        let mut all: Vec<u32> = shards.concat();
        all.sort_unstable();
        assert_eq!(all, nodes);
        // near-equal sizes
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1, "{sizes:?}");
    }

    #[test]
    fn partition_is_seeded() {
        let nodes: Vec<u32> = (0..64).collect();
        assert_eq!(partition_nodes(&nodes, 3, 7), partition_nodes(&nodes, 3, 7));
        assert_ne!(partition_nodes(&nodes, 3, 7), partition_nodes(&nodes, 3, 8));
    }
}
