//! The paper's five evaluation datasets (Table 1), as synthetic analogues.
//!
//! | Dataset       | paper nodes | paper edges | ours (scale)        | task |
//! |---------------|-------------|-------------|---------------------|------|
//! | ogbn-arxiv    | 169,343     | 1,166,243   | 1/8  (21k / 146k)   | NC   |
//! | ogbn-products | 2,449,029   | 61,859,140  | 1/128 (19k / 483k)  | NC   |
//! | Pubmed        | 19,717      | 88,651      | 1/1  (20k / 89k)    | NC   |
//! | DBLP          | 317,080     | 1,049,866   | 1/16 (20k / 66k)    | LP   |
//! | Amazon        | 410,236     | 3,356,824   | 1/24 (17k / 140k)   | LP   |
//!
//! Scales are chosen so every dataset trains in seconds on the CPU substrate
//! while preserving each graph's **average degree** (6.9 / 25.3 / 4.5 / 3.3
//! / 8.2) — the quantity the paper's SPMM/SDDMM results key on (ogbn-products
//! is the dense one, DBLP the sparsest; see Fig. 8 discussion).

use super::generators::{features_for_labels, planted_partition, power_law, random_features};
use super::Coo;
use crate::quant::rng::Xoshiro256pp;
use crate::tensor::Dense;

/// Learning task attached to a dataset (paper Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// Node classification.
    NodeClassification,
    /// Link prediction.
    LinkPrediction,
}

/// A fully materialised dataset: graph + features + labels + split.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Canonical name (paper spelling).
    pub name: &'static str,
    /// The graph, already augmented with reverse edges and self-loops
    /// (paper §4.1).
    pub graph: Coo,
    /// Node feature matrix `[num_nodes, feat_dim]`.
    pub features: Dense<f32>,
    /// Node labels (class ids for NC; community ids for LP negatives).
    pub labels: Vec<u32>,
    /// Number of label classes.
    pub num_classes: usize,
    /// Task type.
    pub task: Task,
    /// Train/validation node masks (by node id ranges of a seeded shuffle).
    pub train_nodes: Vec<u32>,
    /// Held-out evaluation nodes.
    pub eval_nodes: Vec<u32>,
}

/// Static spec of one of the paper's datasets at our scale.
#[derive(Debug, Clone, Copy)]
pub struct DatasetSpec {
    /// Canonical name.
    pub name: &'static str,
    /// Nodes at our scale.
    pub num_nodes: usize,
    /// Directed edges per node for the generator (≈ half the final average
    /// degree, since reverse edges double them).
    pub edges_per_node: usize,
    /// Input feature dimension.
    pub feat_dim: usize,
    /// Label classes.
    pub num_classes: usize,
    /// Task.
    pub task: Task,
    /// Paper-reported node/edge counts (Table 1), for `repro table1`.
    pub paper_nodes: usize,
    /// Paper-reported edge count.
    pub paper_edges: usize,
}

/// All five specs, in the paper's Table 1 order.
pub const SPECS: [DatasetSpec; 5] = [
    DatasetSpec {
        name: "ogbn-arxiv",
        num_nodes: 21_168,
        edges_per_node: 3,
        feat_dim: 128,
        num_classes: 40,
        task: Task::NodeClassification,
        paper_nodes: 169_343,
        paper_edges: 1_166_243,
    },
    DatasetSpec {
        name: "ogbn-products",
        num_nodes: 19_133,
        edges_per_node: 12,
        feat_dim: 100,
        num_classes: 47,
        task: Task::NodeClassification,
        paper_nodes: 2_449_029,
        paper_edges: 61_859_140,
    },
    DatasetSpec {
        name: "Pubmed",
        num_nodes: 19_717,
        edges_per_node: 2,
        feat_dim: 500,
        num_classes: 3,
        task: Task::NodeClassification,
        paper_nodes: 19_717,
        paper_edges: 88_651,
    },
    DatasetSpec {
        name: "DBLP",
        num_nodes: 19_818,
        edges_per_node: 2,
        feat_dim: 128,
        num_classes: 8,
        task: Task::LinkPrediction,
        paper_nodes: 317_080,
        paper_edges: 1_049_866,
    },
    DatasetSpec {
        name: "Amazon",
        num_nodes: 17_093,
        edges_per_node: 4,
        feat_dim: 96,
        num_classes: 16,
        task: Task::LinkPrediction,
        paper_nodes: 410_236,
        paper_edges: 3_356_824,
    },
];

/// Look up a spec by (case-insensitive) name.
pub fn spec(name: &str) -> Option<&'static DatasetSpec> {
    SPECS.iter().find(|s| s.name.eq_ignore_ascii_case(name))
}

/// Materialise a dataset from its spec.
///
/// NC datasets use planted-partition graphs (labels must correlate with
/// structure for GNNs to learn); LP datasets use preferential attachment
/// (link prediction learns from topology alone) with community features.
pub fn load(spec: &DatasetSpec, seed: u64) -> Dataset {
    let (graph, labels) = match spec.task {
        Task::NodeClassification => {
            planted_partition(spec.num_nodes, spec.edges_per_node, spec.num_classes, 0.75, seed)
        }
        Task::LinkPrediction => {
            let g = power_law(spec.num_nodes, spec.edges_per_node, seed);
            let mut rng = Xoshiro256pp::new(seed ^ 0xC0FFEE);
            let labels =
                (0..spec.num_nodes).map(|_| (rng.next_u64() % spec.num_classes as u64) as u32).collect();
            (g, labels)
        }
    };
    let graph = graph.with_reverse_edges().dedup().with_self_loops();
    let features = match spec.task {
        Task::NodeClassification => {
            features_for_labels(&labels, spec.feat_dim, spec.num_classes, 0.6, seed)
        }
        Task::LinkPrediction => random_features(spec.num_nodes, spec.feat_dim, seed),
    };
    // 80/20 split from a seeded shuffle.
    let mut order: Vec<u32> = (0..spec.num_nodes as u32).collect();
    let mut rng = Xoshiro256pp::new(seed ^ 0x5E11);
    for i in (1..order.len()).rev() {
        let j = (rng.next_u64() % (i as u64 + 1)) as usize;
        order.swap(i, j);
    }
    let cut = spec.num_nodes * 4 / 5;
    Dataset {
        name: spec.name,
        graph,
        features,
        labels,
        num_classes: spec.num_classes,
        task: spec.task,
        train_nodes: order[..cut].to_vec(),
        eval_nodes: order[cut..].to_vec(),
    }
}

/// Load by name with the default seed. Panics on unknown names — test and
/// bench convenience only; library paths use [`load_by_name_checked`].
pub fn load_by_name(name: &str, seed: u64) -> Dataset {
    load(spec(name).unwrap_or_else(|| panic!("unknown dataset {name}")), seed)
}

/// Load by name (including the test-scale `"tiny"`), reporting unknown
/// names as an actionable error instead of panicking.
pub fn load_by_name_checked(name: &str, seed: u64) -> Result<Dataset, String> {
    if name.eq_ignore_ascii_case("tiny") {
        return Ok(tiny(seed));
    }
    match spec(name) {
        Some(s) => Ok(load(s, seed)),
        None => Err(format!(
            "unknown dataset {name:?}; known: tiny, {}",
            SPECS.iter().map(|s| s.name).collect::<Vec<_>>().join(", ")
        )),
    }
}

/// A miniature dataset for unit tests and the quickstart example.
pub fn tiny(seed: u64) -> Dataset {
    let spec = DatasetSpec {
        name: "tiny",
        num_nodes: 200,
        edges_per_node: 4,
        feat_dim: 16,
        num_classes: 4,
        task: Task::NodeClassification,
        paper_nodes: 0,
        paper_edges: 0,
    };
    load(&spec, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_specs_resolve_by_name() {
        for s in SPECS.iter() {
            assert!(spec(s.name).is_some());
        }
        assert!(spec("pubmed").is_some(), "case-insensitive lookup");
        assert!(spec("nope").is_none());
    }

    #[test]
    fn checked_loader_resolves_and_reports() {
        assert_eq!(load_by_name_checked("tiny", 1).unwrap().name, "tiny");
        assert_eq!(load_by_name_checked("Pubmed", 1).unwrap().name, "Pubmed");
        let err = load_by_name_checked("nope", 1).unwrap_err();
        assert!(err.contains("unknown dataset") && err.contains("Pubmed"), "{err}");
    }

    #[test]
    fn tiny_dataset_well_formed() {
        let d = tiny(1);
        assert_eq!(d.features.rows(), d.graph.num_nodes);
        assert_eq!(d.labels.len(), d.graph.num_nodes);
        assert_eq!(d.train_nodes.len() + d.eval_nodes.len(), d.graph.num_nodes);
        // Self-loops guarantee every node has an in-edge (paper §4.1).
        assert!(d.graph.in_degrees().iter().all(|&deg| deg >= 1));
    }

    #[test]
    fn splits_are_disjoint() {
        let d = tiny(2);
        let train: std::collections::HashSet<_> = d.train_nodes.iter().collect();
        assert!(d.eval_nodes.iter().all(|v| !train.contains(v)));
    }

    #[test]
    fn average_degrees_match_paper_shape() {
        // ogbn-products must be the densest, DBLP the sparsest — Fig. 8's
        // explanation depends on this ordering.
        let degs: Vec<(&str, f64)> = SPECS
            .iter()
            .map(|s| {
                // generator degree ≈ 2*edges_per_node after reverse edges
                (s.name, 2.0 * s.edges_per_node as f64)
            })
            .collect();
        let products = degs.iter().find(|(n, _)| *n == "ogbn-products").unwrap().1;
        let dblp = degs.iter().find(|(n, _)| *n == "DBLP").unwrap().1;
        assert!(degs.iter().all(|&(_, d)| d <= products));
        assert!(degs.iter().all(|&(_, d)| d >= dblp));
    }

    #[test]
    fn load_is_deterministic() {
        let s = spec("Pubmed").unwrap();
        let a = load(s, 3);
        let b = load(s, 3);
        assert_eq!(a.graph, b.graph);
        assert_eq!(a.features, b.features);
    }

    #[test]
    fn nc_dataset_is_learnable_shape() {
        // Labels must correlate with edges (homophily) for NC datasets.
        let d = load_by_name("ogbn-arxiv", 4);
        let intra = (0..d.graph.num_edges())
            .filter(|&e| d.labels[d.graph.src[e] as usize] == d.labels[d.graph.dst[e] as usize])
            .count() as f64
            / d.graph.num_edges() as f64;
        assert!(intra > 0.5, "homophily too low: {intra}");
    }
}
