//! Graph substrate.
//!
//! Everything the primitives and models need from the graph side:
//!
//! - [`Coo`] — edge-list form, the canonical on-disk/generator format;
//! - [`Csr`] — destination-grouped adjacency (in-edges per node) carrying
//!   per-entry *edge ids*, the layout SPMM/SDDMM and edge-softmax iterate;
//!   its [`Csr::reverse`] is the source-grouped (out-edge) adjacency the
//!   backward pass runs on (paper Fig. 1b);
//! - [`Incidence`] — the node×edge incidence structure behind the paper's
//!   *incidence-matrix-based SPMM* (§3.3, Fig. 5);
//! - [`generators`] — synthetic graph generators (power-law /
//!   preferential-attachment, Erdős–Rényi, planted-partition labels) that
//!   stand in for the paper's datasets;
//! - [`datasets`] — the five evaluation graphs of Table 1 at reduced scale,
//!   matched on average degree and degree shape;
//! - [`partition`] — node partitioning for the multi-worker mini-batch
//!   simulation (paper §4.2 multi-GPU); the neighbour sampling itself lives
//!   in [`crate::sampler`].

mod coo;
mod csr;
pub mod datasets;
pub mod generators;
mod incidence;
pub mod partition;

pub use coo::Coo;
pub use csr::Csr;
pub use incidence::Incidence;
