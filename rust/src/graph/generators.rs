//! Synthetic graph, feature and label generators.
//!
//! The paper evaluates on ogbn-arxiv, ogbn-products, Pubmed, DBLP and
//! Amazon. Those exact datasets (and the hardware to train on the larger
//! ones) are not available here, so — per the substitution rule — we
//! generate graphs matched on the properties that the paper's experiments
//! actually exercise:
//!
//! - node/edge counts (scaled) and **average degree** — drive SPMM/SDDMM
//!   memory behaviour;
//! - a **power-law degree distribution** (preferential attachment) for the
//!   citation/co-purchase graphs — drives access irregularity (Table 2);
//! - **planted community structure** with community-correlated features —
//!   makes node classification and link prediction learnable, so accuracy
//!   recovery (Fig. 2/7) is meaningful.

use crate::graph::Coo;
use crate::quant::rng::Xoshiro256pp;
use crate::tensor::Dense;

/// Erdős–Rényi G(n, m): `m` uniformly random directed edges, no dups.
pub fn erdos_renyi(num_nodes: usize, num_edges: usize, seed: u64) -> Coo {
    let mut rng = Xoshiro256pp::new(seed);
    let mut seen = std::collections::HashSet::with_capacity(num_edges);
    let mut src = Vec::with_capacity(num_edges);
    let mut dst = Vec::with_capacity(num_edges);
    while src.len() < num_edges {
        let s = (rng.next_u64() % num_nodes as u64) as u32;
        let d = (rng.next_u64() % num_nodes as u64) as u32;
        if s != d && seen.insert((s, d)) {
            src.push(s);
            dst.push(d);
        }
    }
    Coo::new(num_nodes, src, dst)
}

/// Preferential attachment (Barabási–Albert flavoured): each new node cites
/// `edges_per_node` earlier nodes with probability proportional to their
/// current degree — yields the heavy-tailed in-degree distribution of
/// citation/co-purchase graphs.
pub fn power_law(num_nodes: usize, edges_per_node: usize, seed: u64) -> Coo {
    assert!(num_nodes > edges_per_node.max(1));
    let mut rng = Xoshiro256pp::new(seed);
    let mut src = Vec::with_capacity(num_nodes * edges_per_node);
    let mut dst = Vec::with_capacity(num_nodes * edges_per_node);
    // `targets` holds one entry per degree unit: sampling uniformly from it
    // is sampling proportional to degree.
    let mut targets: Vec<u32> = (0..edges_per_node.max(2) as u32).collect();
    for v in edges_per_node.max(2)..num_nodes {
        let mut chosen = std::collections::HashSet::new();
        while chosen.len() < edges_per_node {
            let t = targets[(rng.next_u64() % targets.len() as u64) as usize];
            chosen.insert(t);
        }
        // HashSet iteration order varies per process (random hasher seed);
        // the edge list feeds every downstream RNG-consuming stage, so emit
        // the chosen targets in sorted order to keep graphs bit-identical
        // across runs (audit rule D1).
        let mut chosen: Vec<u32> = chosen.into_iter().collect();
        chosen.sort_unstable();
        for &t in &chosen {
            src.push(v as u32);
            dst.push(t);
            targets.push(t);
            targets.push(v as u32);
        }
    }
    Coo::new(num_nodes, src, dst)
}

/// A planted-partition graph: `num_classes` communities; each node draws
/// `edges_per_node` neighbours, intra-community with probability
/// `homophily`, uniform otherwise. Returns the graph and per-node labels.
pub fn planted_partition(
    num_nodes: usize,
    edges_per_node: usize,
    num_classes: usize,
    homophily: f64,
    seed: u64,
) -> (Coo, Vec<u32>) {
    let mut rng = Xoshiro256pp::new(seed);
    let labels: Vec<u32> = (0..num_nodes).map(|_| (rng.next_u64() % num_classes as u64) as u32).collect();
    // Bucket nodes by community for intra-community sampling.
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); num_classes];
    for (v, &c) in labels.iter().enumerate() {
        buckets[c as usize].push(v as u32);
    }
    let mut seen = std::collections::HashSet::new();
    let mut src = Vec::new();
    let mut dst = Vec::new();
    for v in 0..num_nodes as u32 {
        let c = labels[v as usize] as usize;
        let mut placed = 0;
        let mut attempts = 0;
        while placed < edges_per_node && attempts < edges_per_node * 20 {
            attempts += 1;
            let u = if (rng.next_f32() as f64) < homophily && buckets[c].len() > 1 {
                buckets[c][(rng.next_u64() % buckets[c].len() as u64) as usize]
            } else {
                (rng.next_u64() % num_nodes as u64) as u32
            };
            if u != v && seen.insert((v, u)) {
                src.push(v);
                dst.push(u);
                placed += 1;
            }
        }
    }
    (Coo::new(num_nodes, src, dst), labels)
}

/// Community-correlated node features: feature = centroid(label) + noise.
/// Centroids are random unit-ish vectors; `noise` controls task difficulty.
pub fn features_for_labels(labels: &[u32], dim: usize, num_classes: usize, noise: f32, seed: u64) -> Dense<f32> {
    let mut rng = Xoshiro256pp::new(seed ^ 0xFEA7);
    let centroids: Vec<f32> = (0..num_classes * dim).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
    let mut data = Vec::with_capacity(labels.len() * dim);
    for &c in labels {
        for j in 0..dim {
            let base = centroids[c as usize * dim + j];
            data.push(base + noise * (rng.next_f32() * 2.0 - 1.0));
        }
    }
    Dense::from_vec(&[labels.len(), dim], data)
}

/// Uniform random features in `[-1, 1)` (for benches where labels are moot).
pub fn random_features(rows: usize, dim: usize, seed: u64) -> Dense<f32> {
    let mut rng = Xoshiro256pp::new(seed);
    Dense::from_vec(&[rows, dim], (0..rows * dim).map(|_| rng.next_f32() * 2.0 - 1.0).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erdos_renyi_counts() {
        let g = erdos_renyi(100, 500, 1);
        assert_eq!(g.num_nodes, 100);
        assert_eq!(g.num_edges(), 500);
        // no dups, no self loops
        let mut set = std::collections::HashSet::new();
        for e in 0..500 {
            assert!(g.src[e] != g.dst[e]);
            assert!(set.insert((g.src[e], g.dst[e])));
        }
    }

    #[test]
    fn erdos_renyi_deterministic() {
        let a = erdos_renyi(50, 100, 7);
        let b = erdos_renyi(50, 100, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn power_law_has_heavy_tail() {
        let g = power_law(2000, 4, 3);
        let deg = g.in_degrees();
        let max = *deg.iter().max().unwrap() as f64;
        let avg = g.num_edges() as f64 / 2000.0;
        // A heavy tail: hub degree far above the average.
        assert!(max > 10.0 * avg, "max={max} avg={avg}");
    }

    #[test]
    fn power_law_deterministic_and_sorted_per_node() {
        // Regression for the D1 bug class: edge emission used to iterate a
        // HashSet, whose order changes per process. Same-process equality
        // alone cannot catch that, so also pin the per-node target order
        // to be sorted — a process-independent property.
        let a = power_law(300, 3, 9);
        let b = power_law(300, 3, 9);
        assert_eq!(a, b);
        let mut i = 0;
        while i < a.num_edges() {
            let v = a.src[i];
            let mut j = i;
            while j < a.num_edges() && a.src[j] == v {
                j += 1;
            }
            let block = &a.dst[i..j];
            assert!(block.windows(2).all(|w| w[0] < w[1]), "node {v} targets unsorted: {block:?}");
            i = j;
        }
    }

    #[test]
    fn power_law_edge_count() {
        let g = power_law(1000, 5, 11);
        assert_eq!(g.num_edges(), (1000 - 5) * 5);
    }

    #[test]
    fn planted_partition_is_homophilous() {
        let (g, labels) = planted_partition(500, 8, 5, 0.8, 13);
        let intra = (0..g.num_edges())
            .filter(|&e| labels[g.src[e] as usize] == labels[g.dst[e] as usize])
            .count() as f64;
        let frac = intra / g.num_edges() as f64;
        // 0.8 homophily + 1/5 random hits: expect ~0.84 intra-community.
        assert!(frac > 0.6, "intra fraction {frac}");
    }

    #[test]
    fn features_cluster_by_label() {
        let labels = vec![0u32, 0, 1, 1];
        let f = features_for_labels(&labels, 16, 2, 0.05, 5);
        let d = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>()
        };
        let same = d(f.row(0), f.row(1));
        let diff = d(f.row(0), f.row(2));
        assert!(same < diff, "same-label distance {same} >= cross-label {diff}");
    }

    #[test]
    fn random_features_shape_and_range() {
        let f = random_features(10, 8, 2);
        assert_eq!(f.shape(), &[10, 8]);
        assert!(f.data().iter().all(|&x| (-1.0..1.0).contains(&x)));
    }
}
