//! Multi-worker data-parallel training simulation (paper §4.2, Fig. 9).
//!
//! The paper adopts DGL's mini-batch multi-GPU training: each GPU trains on
//! sampled mini-batches, then gradients are all-reduced over PCIe. Tango's
//! win there is **transferring quantized node features and gradients**,
//! which relieves PCIe congestion — so the speedup *grows* with GPU count
//! (1.1×→1.5× on GCN, 1.2×→1.7× on GAT from 2 to 6 GPUs).
//!
//! No GPUs or PCIe exist here, so the computation is real — worker threads
//! run persistent GCN/GAT models over the sampler's [`crate::sampler::Block`]
//! pipeline (per-worker [`crate::sampler::NeighborSampler`] streams —
//! uniform or degree-biased — and one process-wide
//! [`crate::sampler::QuantFeatureStore`] for the feature gathers, driven by
//! the shared degree-aware mixed-precision policy, see [`crate::policy`])
//! and the ring all-reduce is numerically executed — while the
//! *interconnect* is modelled: a bandwidth/latency/contention
//! parameterisation of PCIe over which FP32 or quantized payloads are
//! charged ([`Interconnect`], [`allreduce_payload_bytes`]).
//!
//! The paper's §4.2 sampling/quantization overlap is real too: every worker
//! prefetches its next batches (sampling + quantized gather) on a producer
//! thread while it trains, and [`EpochStats::wait_s`] reports the measured
//! stage-one time the overlap failed to hide (see
//! [`crate::sampler::run_prefetched`]).

mod allreduce;
mod interconnect;
mod worker;

pub use allreduce::{
    allreduce_payload_bits, allreduce_payload_bytes, ring_allreduce, ring_allreduce_bits,
    ring_messages, ring_transfer_bytes,
};
pub use interconnect::Interconnect;
pub use worker::{run_data_parallel, EpochStats, MultiGpuConfig, MultiGpuReport};
