//! Interconnect model: PCIe bandwidth, latency and congestion.

/// A shared-bus interconnect (PCIe-like).
#[derive(Debug, Clone, Copy)]
pub struct Interconnect {
    /// Per-link unidirectional bandwidth (byte/s).
    pub link_bw: f64,
    /// Per-message latency (s).
    pub latency: f64,
    /// Congestion exponent: effective bandwidth per worker degrades as
    /// `link_bw / workers^congestion` when `workers` peers share the bus
    /// (0 = perfect switch, 1 = single shared bus). PCIe trees with a
    /// shared root complex sit in between — the paper's "PCI-E congestion".
    pub congestion: f64,
}

impl Interconnect {
    /// PCIe 3.0 ×16 through a shared root complex (the paper's V100S box).
    pub fn pcie3() -> Self {
        Interconnect { link_bw: 12.8e9, latency: 10e-6, congestion: 0.6 }
    }

    /// Effective per-worker bandwidth with `workers` concurrent peers.
    pub fn effective_bw(&self, workers: usize) -> f64 {
        self.link_bw / (workers.max(1) as f64).powf(self.congestion)
    }

    /// Modelled time to move `bytes` per worker with `workers` concurrent
    /// transfers of `messages` messages each.
    pub fn transfer_time(&self, bytes: f64, messages: usize, workers: usize) -> f64 {
        self.latency * messages as f64 + bytes / self.effective_bw(workers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn congestion_degrades_effective_bandwidth() {
        let ic = Interconnect::pcie3();
        assert!(ic.effective_bw(6) < ic.effective_bw(2));
        assert!(ic.effective_bw(1) <= ic.link_bw + 1.0);
    }

    #[test]
    fn transfer_time_scales_with_bytes_and_messages() {
        let ic = Interconnect::pcie3();
        let t1 = ic.transfer_time(1e6, 1, 2);
        let t2 = ic.transfer_time(2e6, 1, 2);
        let t3 = ic.transfer_time(1e6, 10, 2);
        assert!(t2 > t1);
        assert!(t3 > t1);
    }

    #[test]
    fn quantized_payload_quarter_time_at_scale() {
        // With latency amortised, 1-byte payloads take ~1/4 the time of
        // 4-byte payloads — the Fig. 9 mechanism.
        let ic = Interconnect::pcie3();
        let fp32 = ic.transfer_time(4.0 * 1e8, 1, 4);
        let int8 = ic.transfer_time(1.0 * 1e8, 1, 4);
        let ratio = fp32 / int8;
        assert!(ratio > 3.5 && ratio < 4.5, "{ratio}");
    }
}
