//! Data-parallel workers: real mini-batch training on sampled subgraphs
//! across threads, gradients exchanged via the (numerically real) ring
//! all-reduce, interconnect time *modelled* per DESIGN.md §Substitutions.

use super::allreduce::{ring_allreduce, ring_transfer_bytes};
use super::interconnect::Interconnect;
use crate::config::{ModelKind, TrainConfig};
use crate::graph::datasets::{Dataset, Task};
use crate::graph::partition::{partition_nodes, sample_subgraph};
use crate::graph::Csr;
use crate::model::{softmax_cross_entropy, GatConfig, GatModel, GcnConfig, GcnModel, Sgd};
use crate::util::par;

/// Multi-worker run configuration.
#[derive(Debug, Clone)]
pub struct MultiGpuConfig {
    /// Base training config (model/hidden/mode/seed).
    pub train: TrainConfig,
    /// Number of simulated GPUs (worker threads).
    pub workers: usize,
    /// Epochs to run.
    pub epochs: usize,
    /// Neighbour-sampling fanout.
    pub fanout: usize,
    /// Mini-batch seeds per worker per epoch.
    pub batch_size: usize,
    /// Quantize all-reduce payloads (Tango) or send FP32 (baseline).
    pub quantize_grads: bool,
    /// Overlap the payload quantization with subgraph sampling (paper:
    /// "we overlap the feature quantization with the subgraph sampling").
    pub overlap_quantization: bool,
    /// Interconnect model.
    pub interconnect: Interconnect,
}

/// Per-epoch timing breakdown.
#[derive(Debug, Clone, Copy)]
pub struct EpochStats {
    /// Slowest worker's compute time (real, measured).
    pub compute_s: f64,
    /// Modelled interconnect time for the gradient all-reduce.
    pub comm_s: f64,
    /// Modelled quantization time not hidden behind sampling.
    pub quant_s: f64,
    /// Mean training loss across workers.
    pub loss: f32,
}

impl EpochStats {
    /// Total modelled epoch wall time.
    pub fn total(&self) -> f64 {
        self.compute_s + self.comm_s + self.quant_s
    }
}

/// A whole run's results.
#[derive(Debug, Clone)]
pub struct MultiGpuReport {
    /// Per-epoch stats.
    pub epochs: Vec<EpochStats>,
    /// Gradient elements all-reduced per epoch.
    pub grad_elems: usize,
}

impl MultiGpuReport {
    /// Total modelled wall time.
    pub fn total_time(&self) -> f64 {
        self.epochs.iter().map(|e| e.total()).sum()
    }
}

enum AnyModel {
    Gcn(GcnModel),
    Gat(GatModel),
}

impl AnyModel {
    fn params(&self) -> Vec<f32> {
        match self {
            AnyModel::Gcn(m) => m.params_flat(),
            AnyModel::Gat(m) => m.params_flat(),
        }
    }
    fn set_params(&mut self, p: &[f32]) {
        match self {
            AnyModel::Gcn(m) => m.set_params_flat(p),
            AnyModel::Gat(m) => m.set_params_flat(p),
        }
    }
}

/// Run simulated data-parallel training. Only NC datasets are supported
/// (the paper's multi-GPU experiment trains classification models).
pub fn run_data_parallel(cfg: &MultiGpuConfig, data: &Dataset) -> crate::Result<MultiGpuReport> {
    assert_eq!(data.task, Task::NodeClassification, "multi-GPU sim is NC-only");
    let k = cfg.workers.max(1);
    let shards = partition_nodes(&data.train_nodes, k, cfg.train.seed);
    let csr = Csr::from_coo(&data.graph);
    // Per-worker models, identically initialised (same seed = same params).
    let mut models: Vec<AnyModel> = (0..k)
        .map(|_| match cfg.train.model {
            ModelKind::Gcn => AnyModel::Gcn(GcnModel::new(
                GcnConfig {
                    in_dim: data.features.cols(),
                    hidden: cfg.train.hidden,
                    out_dim: data.num_classes,
                    layers: cfg.train.layers,
                    mode: cfg.train.mode,
                },
                &data.graph,
                cfg.train.seed,
            )),
            ModelKind::Gat => AnyModel::Gat(GatModel::new(
                GatConfig {
                    in_dim: data.features.cols(),
                    hidden: cfg.train.hidden,
                    out_dim: data.num_classes,
                    heads: cfg.train.heads,
                    layers: cfg.train.layers,
                    mode: cfg.train.mode,
                },
                &data.graph,
                cfg.train.seed,
            )),
        })
        .collect();
    let grad_elems = models[0].params();
    let grad_elems = grad_elems.len();

    let mut epochs = Vec::with_capacity(cfg.epochs);
    for epoch in 0..cfg.epochs {
        // Each worker: sample a subgraph batch around its shard and run one
        // real training step on it (threaded, measured).
        let results: Vec<(Vec<f32>, f64, f32)> = par::map_range(k, |w| {
            let shard = &shards[w];
            let take = cfg.batch_size.min(shard.len());
            let seeds = &shard[..take];
            let sub = sample_subgraph(
                &data.graph,
                &csr,
                seeds,
                cfg.fanout,
                cfg.train.seed ^ (epoch as u64) << 8 ^ w as u64,
            );
            let sub_graph = sub.graph.clone().with_self_loops();
            // Gather local features/labels.
            let dim = data.features.cols();
            let mut feats = crate::tensor::Dense::zeros(&[sub.node_map.len(), dim]);
            for (local, &parent) in sub.node_map.iter().enumerate() {
                feats.row_mut(local).copy_from_slice(data.features.row(parent as usize));
            }
            let labels: Vec<u32> =
                sub.node_map.iter().map(|&p| data.labels[p as usize]).collect();
            // One local step on a fresh model carrying the global params.
            let t0 = std::time::Instant::now();
            let mut local = match cfg.train.model {
                ModelKind::Gcn => AnyModel::Gcn(GcnModel::new(
                    GcnConfig {
                        in_dim: dim,
                        hidden: cfg.train.hidden,
                        out_dim: data.num_classes,
                        layers: cfg.train.layers,
                        mode: cfg.train.mode,
                    },
                    &sub_graph,
                    cfg.train.seed,
                )),
                ModelKind::Gat => AnyModel::Gat(GatModel::new(
                    GatConfig {
                        in_dim: dim,
                        hidden: cfg.train.hidden,
                        out_dim: data.num_classes,
                        heads: cfg.train.heads,
                        layers: cfg.train.layers,
                        mode: cfg.train.mode,
                    },
                    &sub_graph,
                    cfg.train.seed,
                )),
            };
            // Continue from the current global parameters (all workers hold
            // identical params after each all-reduce).
            local.set_params(&models[w].params());
            let before = local.params();
            let mut opt = Sgd::new(cfg.train.lr);
            let loss = match &mut local {
                AnyModel::Gcn(m) => {
                    m.train_step(&feats, &mut opt, |lg| {
                        softmax_cross_entropy(lg, &labels, &sub.seeds)
                    })
                    .0
                }
                AnyModel::Gat(m) => {
                    m.train_step(&feats, &mut opt, |lg| {
                        softmax_cross_entropy(lg, &labels, &sub.seeds)
                    })
                    .0
                }
            };
            // Effective gradient = (before - after) / lr.
            let after = local.params();
            let grad: Vec<f32> =
                before.iter().zip(&after).map(|(b, a)| (b - a) / cfg.train.lr).collect();
            (grad, t0.elapsed().as_secs_f64(), loss)
        });
        let compute_s = results.iter().map(|r| r.1).fold(0.0, f64::max);
        let loss = results.iter().map(|r| r.2).sum::<f32>() / k as f32;
        let mut grads: Vec<Vec<f32>> = results.into_iter().map(|r| r.0).collect();
        // Real all-reduce of the gradients.
        ring_allreduce(&mut grads, cfg.quantize_grads, cfg.train.seed ^ epoch as u64);
        // Apply the averaged gradient everywhere.
        for (w, model) in models.iter_mut().enumerate() {
            let mut p = model.params();
            for (pi, gi) in p.iter_mut().zip(&grads[w]) {
                *pi -= cfg.train.lr * gi;
            }
            model.set_params(&p);
        }
        // Modelled interconnect time (paper's PCIe): ring transfer of the
        // gradient payload; quantized payloads are 1 B + per-chunk scales.
        let elem_bytes = if cfg.quantize_grads { 1.0 } else { 4.0 };
        let bytes = ring_transfer_bytes(grad_elems, k, elem_bytes)
            + if cfg.quantize_grads { 8.0 * k as f64 } else { 0.0 };
        let comm_s = cfg.interconnect.transfer_time(bytes, 2 * (k - 1).max(1), k);
        // Quantization cost: hidden behind sampling when overlapped.
        let quant_s = if cfg.quantize_grads && !cfg.overlap_quantization {
            // One pass over the gradient at (modelled) memory speed.
            grad_elems as f64 * 5.0 / 12.8e9
        } else {
            0.0
        };
        epochs.push(EpochStats { compute_s, comm_s, quant_s, loss });
    }
    Ok(MultiGpuReport { epochs, grad_elems })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;

    fn cfg(workers: usize, quantize: bool) -> MultiGpuConfig {
        MultiGpuConfig {
            train: TrainConfig {
                model: ModelKind::Gcn,
                dataset: "tiny".into(),
                epochs: 2,
                lr: 0.05,
                hidden: 8,
                heads: 2,
                layers: 2,
                mode: crate::model::TrainMode::fp32(),
                auto_bits: false,
                seed: 5,
                log_every: 0,
                ..Default::default()
            },
            workers,
            epochs: 2,
            fanout: 4,
            batch_size: 16,
            quantize_grads: quantize,
            overlap_quantization: true,
            interconnect: Interconnect::pcie3(),
        }
    }

    #[test]
    fn runs_and_reports() {
        let data = datasets::tiny(3);
        let r = run_data_parallel(&cfg(3, false), &data).unwrap();
        assert_eq!(r.epochs.len(), 2);
        assert!(r.grad_elems > 0);
        assert!(r.total_time() > 0.0);
    }

    #[test]
    fn quantized_comm_is_cheaper() {
        let data = datasets::tiny(3);
        let fp = run_data_parallel(&cfg(4, false), &data).unwrap();
        let q = run_data_parallel(&cfg(4, true), &data).unwrap();
        let fp_comm: f64 = fp.epochs.iter().map(|e| e.comm_s).sum();
        let q_comm: f64 = q.epochs.iter().map(|e| e.comm_s).sum();
        assert!(q_comm < fp_comm, "{q_comm} vs {fp_comm}");
    }

    #[test]
    fn losses_are_finite_and_decrease_ish() {
        let data = datasets::tiny(4);
        let mut c = cfg(2, true);
        c.epochs = 6;
        let r = run_data_parallel(&c, &data).unwrap();
        assert!(r.epochs.iter().all(|e| e.loss.is_finite()));
        assert!(r.epochs[5].loss <= r.epochs[0].loss + 0.2);
    }

    #[test]
    fn single_worker_has_no_comm() {
        let data = datasets::tiny(5);
        let r = run_data_parallel(&cfg(1, false), &data).unwrap();
        // k=1 ring transfer is 0 bytes; only latency terms remain.
        assert!(r.epochs[0].comm_s < 1e-3);
    }
}
