//! Data-parallel workers on the sampler's `Block` pipeline: every worker
//! owns a persistent model (an [`AnyModel`] behind the [`GnnModel`] trait —
//! the same construction path as the single-GPU trainers) and a seeded
//! [`NeighborSampler`] over the shared in-edge CSR, sweeps its shard in
//! shuffled mini-batches each epoch (the DGL epoch shape), and gathers
//! input features from one process-wide [`QuantFeatureStore`]. After every
//! synchronous step the gradients move through the (numerically real) ring
//! all-reduce, while the *interconnect* time is modelled per DESIGN.md
//! §Substitutions with correct quantized-vs-FP32 byte accounting —
//! including sub-byte packed widths when the run quantizes below INT8
//! ([`allreduce_payload_bits`]).
//!
//! The paper's §4.2 overlap ("we overlap the feature quantization with the
//! subgraph sampling") is **real** here, not modelled: each worker runs
//! stage one — sampling + quantized gather, the exact
//! [`SampleStage`](crate::sampler::SampleStage) definition the single-GPU
//! trainer uses — on its own producer thread, `prefetch` batches ahead of
//! the synchronous training step. [`EpochStats::wait_s`] is the *measured*
//! stage-one time the pipeline failed to hide (with `prefetch = 0` it is
//! the whole inline sample+gather time), replacing the old
//! `overlap_quantization` flag that merely skipped a modelled cost.
//!
//! Both task heads run data-parallel: node classification shards the train
//! nodes, link prediction shards the graph's canonical positive edges
//! ([`EdgeBatcher`]) and trains on edge-seeded blocks with seed-edge
//! exclusion — same batching, same seeds, same loss as
//! [`crate::sampler::MiniBatchTrainer`], so a 1-worker run replays it step
//! for step on either task, with or without prefetch.

use super::allreduce::{allreduce_payload_bits, ring_allreduce_bits, ring_messages};
use super::interconnect::Interconnect;
use crate::ckpt::{fingerprint_of, Checkpoint, Cursor};
use crate::config::{TaskKind, TomlDoc, TrainConfig};
use crate::fault::{poison_lock, recover_poisoned_lock, FaultClass, FaultInjector, FaultReport};
use crate::coordinator::qcache::CacheStats;
use crate::graph::datasets::{Dataset, Task};
use crate::graph::partition::partition_nodes;
use crate::graph::Csr;
use crate::model::{softmax_cross_entropy, AnyModel, GnnModel, ModelSpec, Sgd, TaskHead};
use crate::policy::PolicyGatherReport;
use crate::quant::rng::mix_seeds;
use crate::sampler::{
    adjust_fanouts, shuffled_batches, spawn_producer, BatchTarget, EdgeBatcher, FeatureGather,
    NeighborSampler, PreparedBatch, ProducerHandle, QuantFeatureStore, SampleStage, SamplerBias,
    StageTimes,
};
use crate::util::par;
use std::sync::Mutex;
use std::time::Instant;

/// Multi-worker run configuration.
///
/// The sampler knobs (`fanouts`, `batch_size`, `sample_seed`, `cache_nodes`)
/// and the task override live on [`TrainConfig`] — the *same* knobs `tango
/// train --sampler neighbor` reads, so the single-GPU and multi-GPU paths
/// cannot drift apart.
#[derive(Debug, Clone)]
pub struct MultiGpuConfig {
    /// Base training config (model/hidden/mode/seed + sampler knobs).
    pub train: TrainConfig,
    /// Number of simulated GPUs (worker threads).
    pub workers: usize,
    /// Epochs to run; each epoch sweeps every worker's whole shard once.
    pub epochs: usize,
    /// Quantize all-reduce payloads (Tango) or send FP32 (baseline).
    pub quantize_grads: bool,
    /// Interconnect model.
    pub interconnect: Interconnect,
}

impl MultiGpuConfig {
    /// Defaults around a base training config: 4 workers, 5 epochs, FP32
    /// gradient exchange over PCIe 3.0.
    pub fn new(train: TrainConfig) -> Self {
        MultiGpuConfig {
            train,
            workers: 4,
            epochs: 5,
            quantize_grads: false,
            interconnect: Interconnect::pcie3(),
        }
    }

    /// Parse a full config from TOML text: the `[train]` section (including
    /// the unified sampler knobs `fanouts`/`batch_size`/`sample_seed`/
    /// `cache_nodes`/`prefetch` and `task`) plus a `[multigpu]` section with
    /// `workers`, `epochs`, `quantize_grads` and an optional per-worker
    /// `prefetch` override.
    pub fn from_toml(text: &str) -> Result<Self, String> {
        let mut cfg = Self::new(TrainConfig::from_toml(text)?);
        cfg.apply_toml(text)?;
        Ok(cfg)
    }

    /// Apply just the `[multigpu]` section of `text` over `self` (the
    /// `[train]` section is handled by [`TrainConfig::from_toml`]).
    pub fn apply_toml(&mut self, text: &str) -> Result<(), String> {
        let doc = TomlDoc::parse(text)?;
        if let Some(v) = doc.get("multigpu", "workers") {
            self.workers = v.parse().map_err(|e| format!("workers: {e}"))?;
        }
        if let Some(v) = doc.get("multigpu", "epochs") {
            self.epochs = v.parse().map_err(|e| format!("epochs: {e}"))?;
        }
        if let Some(v) = doc.get("multigpu", "quantize_grads") {
            self.quantize_grads = v
                .parse()
                .map_err(|_| format!("quantize_grads: expected true|false, got '{v}'"))?;
        }
        if let Some(v) = doc.get("multigpu", "prefetch") {
            self.train.sampler.prefetch =
                v.parse().map_err(|e| format!("prefetch: {e}"))?;
        }
        if doc.get("multigpu", "overlap_quantization").is_some() {
            return Err(
                "overlap_quantization is gone — each worker now runs a real prefetch \
                 pipeline (measured overlap, not a modelled cost-skip); tune `prefetch` \
                 instead (0 = sequential)"
                    .to_string(),
            );
        }
        Ok(())
    }
}

/// Per-epoch timing breakdown.
#[derive(Debug, Clone, Copy)]
pub struct EpochStats {
    /// Synchronous mini-batch steps this epoch (max over workers' batch
    /// counts; one ring all-reduce per step).
    pub steps: usize,
    /// Compute time (real, measured): sum over steps of the slowest
    /// worker's training-step time.
    pub compute_s: f64,
    /// Modelled interconnect time for the gradient all-reduces.
    pub comm_s: f64,
    /// Stage-one (sampling + quantized gather) time **not** hidden by the
    /// per-worker prefetch pipeline — real, measured: sum over steps of the
    /// slowest worker's wait on its prepared-batch channel. With
    /// `prefetch = 0` this is the whole inline sample+gather time, so
    /// sequential and pipelined totals compare apples to apples.
    pub wait_s: f64,
    /// Stage-one sampling seconds summed over every worker's producer
    /// (real, measured; overlapped with compute when `prefetch > 0`, so it
    /// does not add into [`total`](Self::total)).
    pub sample_s: f64,
    /// Stage-one feature-gather seconds summed over every worker's
    /// producer (real, measured; overlapped like `sample_s`).
    pub gather_s: f64,
    /// Mean training loss across workers and steps.
    pub loss: f32,
}

impl EpochStats {
    /// Total epoch wall time (measured compute + wait, modelled comm).
    pub fn total(&self) -> f64 {
        self.compute_s + self.comm_s + self.wait_s
    }
}

/// A whole run's results.
#[derive(Debug, Clone)]
pub struct MultiGpuReport {
    /// Per-epoch stats.
    pub epochs: Vec<EpochStats>,
    /// Gradient elements all-reduced per step.
    pub grad_elems: usize,
    /// Process-wide quantized feature-cache statistics (None in FP32 mode).
    pub cache: Option<CacheStats>,
    /// Bytes of INT8 rows held by the shared feature cache at run end.
    pub cache_bytes: usize,
    /// Per-bucket gather accounting of the degree-aware mixed-precision
    /// policy driving the shared store (None in FP32 mode).
    pub policy: Option<PolicyGatherReport>,
    /// Final lockstep model parameters (bit-identity assertions in the
    /// crash/resume tests).
    pub final_params: Vec<f32>,
    /// Fault-injection ledger (`--inject-faults` runs only; `None` when the
    /// harness is off). Lands in the artifact's `fault` section.
    pub fault: Option<FaultReport>,
}

impl MultiGpuReport {
    /// Total modelled wall time.
    pub fn total_time(&self) -> f64 {
        self.epochs.iter().map(|e| e.total()).sum()
    }
}

/// One worker's persistent training state: model + optimizer live across
/// every epoch (a fresh model per epoch would silently reset quantization
/// step counters and redo graph binding work every sweep). The worker's
/// `NeighborSampler` lives *outside* this lock — it is immutable and
/// borrowed by the worker's stage-one producer thread while the training
/// thread holds the model.
struct WorkerState {
    model: AnyModel,
    opt: Sgd,
}

/// Where a worker's prepared batches come from this epoch: its stage-one
/// producer thread (`prefetch > 0`) or inline assembly on the training
/// thread (`prefetch = 0` — the sequential baseline).
enum BatchSource<'scope, 'a> {
    Inline(Mutex<SampleStage<'a>>),
    Prefetched(Mutex<ProducerHandle<'scope, PreparedBatch>>),
}

fn build_model(cfg: &TrainConfig, data: &Dataset, out_dim: usize) -> AnyModel {
    AnyModel::new_from_config(
        &ModelSpec::from_train(cfg, data.features.cols(), out_dim),
        &data.graph,
        cfg.seed,
    )
}

/// Run simulated data-parallel training on either task head.
///
/// Every epoch each worker sweeps its shard (train nodes for NC, canonical
/// positive edges for LP) once in shuffled mini-batches (reshuffled per
/// epoch — no element is stuck outside the fixed prefix of its shard),
/// sampling [`crate::sampler::Block`]s with its own splitmix64-mixed
/// stream. With one worker and `quantize_grads` off, the run replays
/// [`crate::sampler::MiniBatchTrainer`] step for step.
pub fn run_data_parallel(cfg: &MultiGpuConfig, data: &Dataset) -> crate::Result<MultiGpuReport> {
    cfg.train.validate().map_err(|e| anyhow::anyhow!(e))?;
    let k = cfg.workers.max(1);
    let train = &cfg.train;
    let task = TaskKind::resolve(train.task, data.task);
    let head = TaskHead::for_task(task);
    let batch_size = train.sampler.batch_size;
    let fanouts = adjust_fanouts(&train.sampler.fanouts, train.layers);
    // LP shards the canonical positive edges; NC shards the train nodes.
    let batcher = match task {
        Task::LinkPrediction => Some(EdgeBatcher::new(&data.graph)),
        Task::NodeClassification => None,
    };
    let shard_items: Vec<u32> = match &batcher {
        Some(b) => b.edge_ids(),
        None => data.train_nodes.clone(),
    };
    // k=1 keeps the natural order so the sweep is identical to the
    // single-GPU MiniBatchTrainer's; k>1 shards a seeded shuffle.
    let shards: Vec<Vec<u32>> = if k == 1 {
        vec![shard_items]
    } else {
        partition_nodes(&shard_items, k, train.seed)
    };
    let csr_in = Csr::from_coo(&data.graph);
    let degrees = data.graph.in_degrees();
    // One process-wide quantized feature store: the feature table is static,
    // so all workers share a single degree-bucketed policy (per-bucket
    // static scales) and one hot-node row cache instead of quantizing
    // per-worker copies (the BiFeat amortisation). The default uniform
    // policy is the original single shared scale, bit for bit.
    let store: Option<Mutex<QuantFeatureStore>> = if train.mode.quantize {
        let policy = train
            .policy
            .materialize(train.mode.bits, &degrees, &data.features)
            .map_err(|e| anyhow::anyhow!(e))?;
        Some(Mutex::new(QuantFeatureStore::with_policy(policy, train.sampler.cache_nodes)))
    } else {
        None
    };
    let out_dim = head.out_dim(data, train.hidden);
    // Persistent per-worker state; identical seeds → identical initial
    // params, and the per-step averaged update keeps them in lockstep.
    let workers: Vec<Mutex<WorkerState>> = (0..k)
        .map(|_| {
            Mutex::new(WorkerState {
                model: build_model(train, data, out_dim),
                opt: Sgd::new(train.lr),
            })
        })
        .collect();
    // Per-worker samplers, outside the worker lock: stage one borrows them
    // on the producer threads while the training threads hold the models.
    let bias = SamplerBias::from_config(&train.sampler);
    let samplers: Vec<NeighborSampler> = (0..k)
        .map(|w| {
            NeighborSampler::with_bias(
                fanouts.clone(),
                mix_seeds(&[train.sampler.seed, train.seed, w as u64]),
                bias,
            )
        })
        .collect();
    let grad_elems =
        workers[0].lock().unwrap_or_else(|e| e.into_inner()).model.num_params();
    let prefetch = train.sampler.prefetch;
    // Quantized gradient exchange rides at the run's quantized width
    // (INT8 by default; sub-byte modes pack sub-byte wire elements). FP32
    // execution modes keep the historical INT8 wire when quantize_grads is
    // on — there is no narrower width to inherit.
    let grad_bits = if train.mode.quantize { train.mode.bits } else { 8 };
    let wire_bits = if cfg.quantize_grads { Some(grad_bits) } else { None };

    // Per-epoch batch counts are shuffle-invariant (shuffling permutes a
    // shard, never resizes it), so the checkpoint cadence, the fault
    // schedules and the resume replay of every worker's step counter all
    // derive from the same deterministic `lens`.
    let lens: Vec<usize> = shards.iter().map(|s| s.len().div_ceil(batch_size)).collect();
    let steps_per_epoch = lens.iter().copied().max().unwrap_or(0);
    let fingerprint = fingerprint_of(train, k, true);
    let policy_scales: Option<Vec<f32>> = store.as_ref().map(|m| {
        let g = m.lock().unwrap_or_else(|e| e.into_inner());
        let p = g.policy();
        (0..p.num_buckets()).map(|b| p.scale(b)).collect()
    });

    let mut epochs = Vec::with_capacity(cfg.epochs);
    let mut start_epoch = 0usize;
    let mut resume_round = 0usize;
    let mut resume_acc = (0.0f32, 0usize);
    if let Some(path) = train.ckpt.resume.clone() {
        let ck = Checkpoint::load(&path)?;
        ck.validate_resume("multigpu", &fingerprint)?;
        if let (Some(stored), Some(current)) = (&ck.policy_scales, &policy_scales) {
            if stored != current {
                anyhow::bail!(
                    "--resume checkpoint {path}: stored policy scales differ from this \
                     run's materialized policy — the dataset features or the \
                     degree-buckets/bucket-bits config changed since the checkpoint"
                );
            }
        }
        let (e, s) = (ck.cursor.epoch, ck.cursor.step);
        // Workers re-enter lockstep with the checkpointed params; each
        // worker's step counter (its stochastic-rounding stream descriptor)
        // is replayed from its deterministic participation count — a worker
        // steps in round `r` of an epoch iff `r < lens[w]`.
        for (w, ws) in workers.iter().enumerate() {
            let mut g = ws.lock().unwrap_or_else(|err| err.into_inner());
            g.model.set_params_flat(&ck.params);
            g.model.set_step_count((e * lens[w] + s.min(lens[w])) as u64);
            g.opt.import_velocity(ck.velocity.clone());
        }
        let expect0 = (e * lens[0] + s.min(lens[0])) as u64;
        if ck.step_count != expect0 {
            anyhow::bail!(
                "--resume checkpoint {path}: stored step_count {} does not match the \
                 replayed count {expect0} at cursor (epoch {e}, step {s}) — shard sizes or \
                 batch size changed since the checkpoint",
                ck.step_count
            );
        }
        // Completed epochs carry their checkpointed losses but no timings.
        for le in 0..e.min(cfg.epochs) {
            epochs.push(EpochStats {
                steps: steps_per_epoch,
                compute_s: 0.0,
                comm_s: 0.0,
                wait_s: 0.0,
                sample_s: 0.0,
                gather_s: 0.0,
                loss: ck.losses.get(le).copied().unwrap_or(0.0) as f32,
            });
        }
        start_epoch = e;
        if s > 0 || ck.cursor.loss_steps > 0 {
            resume_round = s;
            resume_acc = (ck.cursor.loss_sum as f32, ck.cursor.loss_steps);
        }
        crate::obs::counter_add(crate::obs::keys::CTR_CKPT_RESUMES, 1);
    }
    let mut injector = FaultInjector::new(&train.fault);
    for epoch in start_epoch..cfg.epochs {
        // Per-epoch reshuffle of every shard (same mixer as the single-GPU
        // sweep) — the fix for the "same fixed prefix every epoch" bug.
        let shuffle_seed = mix_seeds(&[train.seed, epoch as u64]);
        let batches: Vec<Vec<Vec<u32>>> =
            shards.iter().map(|s| shuffled_batches(s, batch_size, shuffle_seed)).collect();
        let steps = batches.iter().map(|b| b.len()).max().unwrap_or(0);
        debug_assert_eq!(steps, steps_per_epoch);
        // Mid-epoch resume: the first epoch after --resume fast-forwards to
        // the checkpoint's round cursor and re-enters with its checkpointed
        // loss accumulator; later epochs start from round 0 as usual.
        let skip = if epoch == start_epoch { resume_round.min(steps) } else { 0 };
        let acc = if epoch == start_epoch { resume_acc } else { (0.0f32, 0usize) };
        // The whole epoch runs inside one thread scope: each worker's
        // stage-one producer prefetches its shard's batches while the
        // synchronous step rounds below consume them.
        let _epoch_span = crate::obs::span(crate::obs::keys::SPAN_MG_EPOCH);
        // One shared stage-one time account for the epoch: every worker's
        // producer charges into it (atomics), so `EpochStats` reports the
        // summed sample/gather work across all workers.
        let times = StageTimes::default();
        let stat = std::thread::scope(|scope| -> crate::Result<EpochStats> {
            let sources: Vec<BatchSource> = (0..k)
                .map(|w| {
                    let mut st = SampleStage {
                        sampler: &samplers[w],
                        csr_in: &csr_in,
                        degrees: &degrees,
                        labels: &data.labels,
                        lp: batcher.as_ref().map(|b| (b, head.neg_per_pos())),
                        gather: FeatureGather::shared(&data.features, store.as_ref()),
                        packed: train.packed_compute,
                        times: &times,
                    };
                    let wb = &batches[w];
                    if prefetch == 0 {
                        BatchSource::Inline(Mutex::new(st))
                    } else {
                        BatchSource::Prefetched(Mutex::new(spawn_producer(
                            scope,
                            prefetch,
                            wb.len().saturating_sub(skip),
                            move |bi| {
                                // Timeline lane: this producer works for
                                // simulated worker `w` (coordinator = pid 0).
                                let _pid = crate::obs::trace_pid_scope(w as u32 + 1);
                                let abs = skip + bi;
                                st.prepare(&wb[abs], mix_seeds(&[epoch as u64, abs as u64]))
                            },
                        )))
                    }
                })
                .collect();
            let mut compute_s = 0.0f64;
            let mut comm_s = 0.0f64;
            let mut wait_s = 0.0f64;
            let (mut loss_sum, mut loss_n) = acc;
            for step in skip..steps {
                let gr = (epoch * steps_per_epoch + step) as u64;
                // Round-entry faults fire on the coordinator thread before
                // any worker steps, so a recovered fault leaves round-entry
                // state — and therefore the numerics — untouched.
                let mut degraded: Option<usize> = None;
                if let Some(inj) = injector.as_mut() {
                    if inj.fire(FaultClass::Lock, gr) {
                        // Poison + recover the real shared-state mutex when
                        // the run has one; FP32 runs exercise the identical
                        // recovery path on a scratch mutex.
                        match store.as_ref() {
                            Some(m) => {
                                poison_lock(m);
                                recover_poisoned_lock(m, inj);
                            }
                            None => {
                                let scratch = Mutex::new(());
                                poison_lock(&scratch);
                                recover_poisoned_lock(&scratch, inj);
                            }
                        }
                        crate::obs::instant(crate::obs::keys::EVT_RECOVERY_LOCK);
                        if crate::obs::flight_dump(crate::obs::keys::EVT_RECOVERY_LOCK) {
                            inj.report.flight_dumps += 1;
                            crate::obs::counter_add(crate::obs::keys::CTR_FAULT_FLIGHT_DUMPS, 1);
                        }
                    }
                    let mut failures = 0usize;
                    while inj.fire(FaultClass::Worker, gr) {
                        failures += 1;
                        let victim = inj.victim(gr, k);
                        if failures > inj.max_retries {
                            anyhow::bail!(
                                "worker {victim} failed at global step {gr} and the retry \
                                 budget ({}) is exhausted — rerun with --resume {} to rebuild \
                                 from the last checkpoint",
                                inj.max_retries,
                                train.ckpt.path
                            );
                        }
                        inj.charge_backoff(failures);
                        // Rebuild: all workers hold identical params entering
                        // the round (broadcast invariant), so copying from
                        // the next peer restores the victim bit-exactly. Its
                        // own step counter survives the rebuild — shards may
                        // be uneven, so counters legitimately differ.
                        let peer = (victim + 1) % k;
                        let params = workers[peer]
                            .lock()
                            .unwrap_or_else(|e| e.into_inner())
                            .model
                            .params_flat();
                        workers[victim]
                            .lock()
                            .unwrap_or_else(|e| e.into_inner())
                            .model
                            .set_params_flat(&params);
                        inj.report.worker_rebuilds += 1;
                        crate::obs::counter_add(crate::obs::keys::CTR_FAULT_WORKER_REBUILDS, 1);
                        crate::obs::instant(crate::obs::keys::EVT_RECOVERY_WORKER_REBUILD);
                        if crate::obs::flight_dump(crate::obs::keys::EVT_RECOVERY_WORKER_REBUILD) {
                            inj.report.flight_dumps += 1;
                            crate::obs::counter_add(crate::obs::keys::CTR_FAULT_FLIGHT_DUMPS, 1);
                        }
                    }
                }
                // Synchronous round: each worker with a batch left takes its
                // prepared batch (prefetched or assembled inline — either
                // way the same `SampleStage::prepare` definition the
                // single-GPU `MiniBatchTrainer` runs, so the 1-worker
                // step-for-step replay cannot drift) and runs one real
                // train_step_blocks on its own model (threaded, measured).
                type StepOut = (Vec<f32>, Vec<f32>, f64, f64, f32);
                let results: Vec<Option<crate::Result<StepOut>>> = par::map_range(k, |w| {
                    if step >= batches[w].len() {
                        return None;
                    }
                    let t_wait = Instant::now();
                    let prepared = match &sources[w] {
                        BatchSource::Inline(stage) => {
                            stage.lock().unwrap_or_else(|e| e.into_inner()).prepare(
                                &batches[w][step],
                                mix_seeds(&[epoch as u64, step as u64]),
                            )
                        }
                        BatchSource::Prefetched(handle) => {
                            match handle.lock().unwrap_or_else(|e| e.into_inner()).recv() {
                                Ok(Some(p)) => p,
                                Ok(None) => {
                                    return Some(Err(anyhow::anyhow!(
                                        "worker {w}: prefetch ended early at step {step}"
                                    )))
                                }
                                Err(e) => return Some(Err(e)),
                            }
                        }
                    };
                    let wait = t_wait.elapsed().as_secs_f64();
                    let mut guard = workers[w].lock().unwrap_or_else(|e| e.into_inner());
                    let ws = &mut *guard;
                    let _pid = crate::obs::trace_pid_scope(w as u32 + 1);
                    let _step_span = crate::obs::span(crate::obs::keys::SPAN_WORKER_STEP);
                    let t0 = Instant::now();
                    let before = ws.model.params_flat();
                    let loss = match &prepared.target {
                        BatchTarget::Nc { labels } => {
                            let nodes: Vec<u32> = (0..labels.len() as u32).collect();
                            ws.model
                                .train_step_input(
                                    &prepared.blocks,
                                    &prepared.x0,
                                    &mut ws.opt,
                                    &mut |lg| softmax_cross_entropy(lg, labels, &nodes),
                                )
                                .0
                        }
                        BatchTarget::Lp { pairs } => {
                            ws.model
                                .train_step_input(
                                    &prepared.blocks,
                                    &prepared.x0,
                                    &mut ws.opt,
                                    &mut |emb| TaskHead::lp_loss_grad(emb, pairs),
                                )
                                .0
                        }
                    };
                    // Effective gradient = (before - after) / lr.
                    let after = ws.model.params_flat();
                    let grad: Vec<f32> =
                        before.iter().zip(&after).map(|(b, a)| (b - a) / train.lr).collect();
                    Some(Ok((before, grad, wait, t0.elapsed().as_secs_f64(), loss)))
                });
                let mut before: Option<Vec<f32>> = None;
                let mut grads: Vec<Vec<f32>> = Vec::with_capacity(k);
                let mut participants: Vec<usize> = Vec::with_capacity(k);
                let mut round_compute = 0.0f64;
                let mut round_wait = 0.0f64;
                for (w, r) in results.into_iter().enumerate() {
                    let Some(r) = r else { continue };
                    let (b, g, wait, secs, loss) = r?;
                    // All workers hold identical params entering the round,
                    // so any participant's `before` is *the* pre-step state.
                    if before.is_none() {
                        before = Some(b);
                    }
                    grads.push(g);
                    participants.push(w);
                    round_compute = round_compute.max(secs);
                    round_wait = round_wait.max(wait);
                    loss_sum += loss;
                    loss_n += 1;
                }
                let Some(before) = before else { continue };
                compute_s += round_compute;
                wait_s += round_wait;
                // Wire bytes of one full ring pass, computed *before* the
                // link-retry loop so every retry re-charges a complete
                // re-transmission through the interconnect model.
                let bytes = allreduce_payload_bits(grad_elems, k, wire_bits);
                if let Some(inj) = injector.as_mut() {
                    let mut drops = 0usize;
                    while inj.fire(FaultClass::Link, gr) {
                        drops += 1;
                        if drops > inj.max_retries {
                            // Retry budget exhausted: degrade this round to a
                            // skip-straggler all-reduce over the survivors.
                            degraded = Some(inj.victim(gr, k));
                            inj.report.allreduce_degraded += 1;
                            crate::obs::counter_add(
                                crate::obs::keys::CTR_FAULT_ALLREDUCE_DEGRADED,
                                1,
                            );
                            crate::obs::instant(crate::obs::keys::EVT_RECOVERY_ALLREDUCE_DEGRADE);
                            if crate::obs::flight_dump(
                                crate::obs::keys::EVT_RECOVERY_ALLREDUCE_DEGRADE,
                            ) {
                                inj.report.flight_dumps += 1;
                                crate::obs::counter_add(
                                    crate::obs::keys::CTR_FAULT_FLIGHT_DUMPS,
                                    1,
                                );
                            }
                            break;
                        }
                        inj.charge_backoff(drops);
                        inj.report.link_retries += 1;
                        crate::obs::counter_add(crate::obs::keys::CTR_FAULT_LINK_RETRIES, 1);
                        crate::obs::instant(crate::obs::keys::EVT_RECOVERY_LINK_RETRY);
                        if crate::obs::flight_dump(crate::obs::keys::EVT_RECOVERY_LINK_RETRY) {
                            inj.report.flight_dumps += 1;
                            crate::obs::counter_add(crate::obs::keys::CTR_FAULT_FLIGHT_DUMPS, 1);
                        }
                        // Re-transmission cost of the retried ring pass.
                        comm_s += cfg.interconnect.transfer_time(bytes, ring_messages(k), k);
                    }
                }
                // Real all-reduce of the participating gradients (workers
                // whose shard ran dry this round contribute nothing but
                // still receive the averaged update below, staying in
                // lockstep). A degraded round first drops the straggler's
                // gradient, then averages the survivors — every worker still
                // adopts the (changed) mean, so lockstep is preserved.
                let ar_seed = mix_seeds(&[train.seed, epoch as u64, step as u64]);
                if let Some(victim) = degraded {
                    if let Some(vi) = participants.iter().position(|&p| p == victim) {
                        if grads.len() > 1 {
                            grads.remove(vi);
                        }
                    }
                }
                ring_allreduce_bits(&mut grads, wire_bits, ar_seed);
                crate::obs::counter_add(
                    crate::obs::keys::CTR_MULTIGPU_ALLREDUCE_WIRE_BYTES,
                    bytes as u64,
                );
                comm_s += cfg.interconnect.transfer_time(bytes, ring_messages(k), k);
                // Apply the averaged gradient everywhere. A single FP32
                // worker already holds exactly this state (mean of one
                // gradient), so skip the rewrite and stay bitwise equal to
                // MiniBatchTrainer.
                if k > 1 || cfg.quantize_grads {
                    let mut p = before;
                    for (pi, gi) in p.iter_mut().zip(&grads[0]) {
                        *pi -= train.lr * gi;
                    }
                    for ws in &workers {
                        ws.lock().unwrap_or_else(|e| e.into_inner()).model.set_params_flat(&p);
                    }
                }
                // Round-boundary checkpoint, written *after* the broadcast so
                // it captures the exact lockstep state the next round enters
                // with; any worker's params would do, worker 0's are taken.
                if train.ckpt.every > 0 && (gr + 1) % train.ckpt.every as u64 == 0 {
                    let g0 = workers[0].lock().unwrap_or_else(|e| e.into_inner());
                    let ck = Checkpoint {
                        command: "multigpu".to_string(),
                        fingerprint: fingerprint.clone(),
                        cursor: Cursor {
                            epoch,
                            step: step + 1,
                            loss_sum: loss_sum as f64,
                            loss_steps: loss_n,
                        },
                        step_count: g0.model.step_count(),
                        params: g0.model.params_flat(),
                        velocity: g0.opt.export_velocity(),
                        policy_scales: policy_scales.clone(),
                        losses: epochs.iter().map(|st| st.loss as f64).collect(),
                        evals: Vec::new(),
                    };
                    drop(g0);
                    ck.save(&train.ckpt.path)?;
                }
            }
            let loss = if loss_n == 0 { 0.0 } else { loss_sum / loss_n as f32 };
            Ok(EpochStats {
                steps,
                compute_s,
                comm_s,
                wait_s,
                sample_s: times.sample_s(),
                gather_s: times.gather_s(),
                loss,
            })
        })?;
        epochs.push(stat);
    }
    // Run-complete checkpoint: the cursor says "nothing left to replay", and
    // CI byte-compares this file between interrupted-and-resumed and
    // uninterrupted runs.
    if train.ckpt.every > 0 {
        let g0 = workers[0].lock().unwrap_or_else(|e| e.into_inner());
        let ck = Checkpoint {
            command: "multigpu".to_string(),
            fingerprint,
            cursor: Cursor { epoch: cfg.epochs, step: 0, loss_sum: 0.0, loss_steps: 0 },
            step_count: g0.model.step_count(),
            params: g0.model.params_flat(),
            velocity: g0.opt.export_velocity(),
            policy_scales,
            losses: epochs.iter().map(|st| st.loss as f64).collect(),
            evals: Vec::new(),
        };
        drop(g0);
        ck.save(&train.ckpt.path)?;
    }
    let final_params = workers[0].lock().unwrap_or_else(|e| e.into_inner()).model.params_flat();
    let (cache, cache_bytes, policy) = match store {
        Some(m) => {
            let s = m.into_inner().unwrap_or_else(|e| e.into_inner());
            (Some(s.stats()), s.cached_bytes(), Some(s.policy_report()))
        }
        None => (None, 0, None),
    };
    Ok(MultiGpuReport {
        epochs,
        grad_elems,
        cache,
        cache_bytes,
        policy,
        final_params,
        fault: injector.map(|i| i.report),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelKind;
    use crate::graph::datasets;

    fn cfg(workers: usize, quantize: bool) -> MultiGpuConfig {
        let mut train = TrainConfig {
            model: ModelKind::Gcn,
            dataset: "tiny".into(),
            epochs: 2,
            lr: 0.05,
            hidden: 8,
            heads: 2,
            layers: 2,
            mode: crate::model::TrainMode::fp32(),
            auto_bits: false,
            seed: 5,
            log_every: 0,
            ..Default::default()
        };
        train.sampler.fanouts = vec![4, 4];
        train.sampler.batch_size = 16;
        MultiGpuConfig {
            train,
            workers,
            epochs: 2,
            quantize_grads: quantize,
            interconnect: Interconnect::pcie3(),
        }
    }

    #[test]
    fn runs_and_reports() {
        let data = datasets::tiny(3);
        let r = run_data_parallel(&cfg(3, false), &data).unwrap();
        assert_eq!(r.epochs.len(), 2);
        assert!(r.grad_elems > 0);
        assert!(r.total_time() > 0.0);
        // tiny: 160 train nodes over 3 shards, batches of 16 → 4 steps.
        assert!(r.epochs[0].steps >= 4, "{}", r.epochs[0].steps);
        // FP32: no shared quantized store.
        assert!(r.cache.is_none());
    }

    #[test]
    fn quantized_comm_is_cheaper() {
        let data = datasets::tiny(3);
        let fp = run_data_parallel(&cfg(4, false), &data).unwrap();
        let q = run_data_parallel(&cfg(4, true), &data).unwrap();
        let fp_comm: f64 = fp.epochs.iter().map(|e| e.comm_s).sum();
        let q_comm: f64 = q.epochs.iter().map(|e| e.comm_s).sum();
        assert!(q_comm < fp_comm, "{q_comm} vs {fp_comm}");
    }

    #[test]
    fn losses_are_finite_and_decrease_ish() {
        let data = datasets::tiny(4);
        let mut c = cfg(2, true);
        c.epochs = 6;
        let r = run_data_parallel(&c, &data).unwrap();
        assert!(r.epochs.iter().all(|e| e.loss.is_finite()));
        assert!(r.epochs[5].loss <= r.epochs[0].loss + 0.2);
    }

    #[test]
    fn single_worker_has_no_comm() {
        let data = datasets::tiny(5);
        let r = run_data_parallel(&cfg(1, false), &data).unwrap();
        // k=1 ring transfer is 0 bytes and 0 messages.
        assert!(r.epochs[0].comm_s < 1e-9);
    }

    #[test]
    fn epoch_sweep_visits_every_shard_node() {
        // The bug this run shape fixes: the old path trained on the same
        // `&shard[..batch_size]` prefix every epoch. A sweep must cover the
        // whole shard: steps × batch_size ≥ shard size for every worker.
        let data = datasets::tiny(6);
        let c = cfg(2, false);
        let r = run_data_parallel(&c, &data).unwrap();
        let per_worker = data.train_nodes.len().div_ceil(2);
        let need = per_worker.div_ceil(16);
        assert_eq!(r.epochs[0].steps, need, "sweep must cover each shard");
    }

    #[test]
    fn quantized_run_surfaces_shared_cache_stats() {
        let data = datasets::tiny(7);
        let mut c = cfg(2, false);
        c.train.mode = crate::model::TrainMode::tango(8);
        let r = run_data_parallel(&c, &data).unwrap();
        let stats = r.cache.expect("quantized run shares one feature store");
        assert!(stats.hits + stats.misses > 0, "{stats:?}");
        assert!(r.cache_bytes > 0);
        // The default uniform policy reports one INT8 bucket, packed 1:1.
        let policy = r.policy.expect("quantized run reports its policy");
        assert!(!policy.is_mixed());
        assert_eq!(policy.bits, vec![8]);
        assert_eq!(policy.packed_bytes(), policy.int8_bytes());
    }

    #[test]
    fn mixed_policy_and_degree_sampler_run_data_parallel() {
        let data = datasets::tiny(8);
        let mut c = cfg(2, true);
        c.train.mode = crate::model::TrainMode::tango(8);
        c.train.sampler.degree_biased = true;
        c.train.policy.degree_buckets = vec![6, 12];
        c.train.policy.bucket_bits = vec![8, 6, 4];
        let r = run_data_parallel(&c, &data).unwrap();
        assert!(r.epochs.iter().all(|e| e.loss.is_finite()));
        let policy = r.policy.expect("mixed run reports its policy");
        assert!(policy.is_mixed());
        assert_eq!(policy.bits, vec![8, 6, 4]);
        assert!(
            policy.packed_bytes() < policy.int8_bytes(),
            "sub-INT8 buckets must shrink the gathered bytes: {} vs {}",
            policy.packed_bytes(),
            policy.int8_bytes()
        );
        // Deterministic under the mixed policy too.
        let again = run_data_parallel(&c, &data).unwrap();
        let l = |r: &MultiGpuReport| r.epochs.iter().map(|e| e.loss).collect::<Vec<f32>>();
        assert_eq!(l(&r), l(&again));
    }

    #[test]
    fn packed_compute_runs_data_parallel() {
        // Workers consume still-packed gather rows (train_step_input's
        // Packed arm) — finite losses, deterministic replay.
        let data = datasets::tiny(10);
        let mut c = cfg(2, false);
        c.train.mode = crate::model::TrainMode::tango(8);
        c.train.packed_compute = true;
        let r = run_data_parallel(&c, &data).unwrap();
        assert!(r.epochs.iter().all(|e| e.loss.is_finite()));
        let again = run_data_parallel(&c, &data).unwrap();
        let l = |r: &MultiGpuReport| r.epochs.iter().map(|e| e.loss).collect::<Vec<f32>>();
        assert_eq!(l(&r), l(&again));
    }

    #[test]
    fn linkpred_trains_data_parallel() {
        // Edge-sharded LP across 3 workers: finite losses, real steps.
        let data = datasets::load_by_name("DBLP", 5);
        let mut c = cfg(3, false);
        c.train.sampler.batch_size = 512;
        c.epochs = 2;
        c.train.epochs = 2;
        let r = run_data_parallel(&c, &data).unwrap();
        assert_eq!(r.epochs.len(), 2);
        assert!(r.epochs[0].steps > 0);
        assert!(r.epochs.iter().all(|e| e.loss.is_finite()));
    }

    #[test]
    fn toml_roundtrip_parses_multigpu_section() {
        let text = r#"
[train]
model = "gcn"
dataset = "tiny"
task = "linkpred"
fanouts = "6,4"
batch_size = 32
sample_seed = 9
cache_nodes = 128

[multigpu]
workers = 5
epochs = 7
quantize_grads = true
prefetch = 3
"#;
        let cfg = MultiGpuConfig::from_toml(text).unwrap();
        assert_eq!(cfg.workers, 5);
        assert_eq!(cfg.epochs, 7);
        assert!(cfg.quantize_grads);
        assert_eq!(cfg.train.sampler.fanouts, vec![6, 4]);
        assert_eq!(cfg.train.sampler.batch_size, 32);
        assert_eq!(cfg.train.sampler.seed, 9);
        assert_eq!(cfg.train.sampler.cache_nodes, 128);
        // [multigpu] prefetch overrides the shared [train] knob.
        assert_eq!(cfg.train.sampler.prefetch, 3);
        assert_eq!(cfg.train.task, Some(crate::config::TaskKind::LinkPrediction));
        // Booleans validate strictly — a typo must not silently flip the
        // run back to the FP32 baseline.
        let err = MultiGpuConfig::from_toml("[multigpu]\nquantize_grads = 1\n").unwrap_err();
        assert!(err.contains("quantize_grads"), "{err}");
        // The retired flag is rejected with a pointer at its replacement,
        // not silently ignored.
        let err = MultiGpuConfig::from_toml("[multigpu]\noverlap_quantization = true\n")
            .unwrap_err();
        assert!(err.contains("prefetch"), "{err}");
    }

    #[test]
    fn prefetched_and_sequential_workers_match_bitwise() {
        // The real overlap must not change a single loss at any worker
        // count (per-batch RNG streams are position-keyed, and stage one is
        // the same definition either way).
        let data = datasets::tiny(9);
        for workers in [1usize, 3] {
            let losses = |prefetch: usize| {
                let mut c = cfg(workers, false);
                c.train.mode = crate::model::TrainMode::tango(8);
                c.train.sampler.prefetch = prefetch;
                let r = run_data_parallel(&c, &data).unwrap();
                r.epochs.iter().map(|e| e.loss).collect::<Vec<f32>>()
            };
            assert_eq!(losses(0), losses(2), "workers={workers}");
        }
    }
}
