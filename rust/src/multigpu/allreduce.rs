//! Ring all-reduce, executed numerically (the values really move through
//! per-worker chunks) with optional INT8 payload quantization — the paper's
//! "transferring the quantized node features and gradients".

use crate::quant::{dequantize, quantize, QTensor, Rounding};
use crate::tensor::Dense;

/// Bytes each worker sends over the wire for one ring all-reduce of an
/// `n`-element vector across `k` workers (reduce-scatter + all-gather:
/// `2·(k-1)/k · n · elem_bytes`).
pub fn ring_transfer_bytes(n: usize, k: usize, elem_bytes: f64) -> f64 {
    if k <= 1 {
        return 0.0;
    }
    2.0 * (k as f64 - 1.0) / k as f64 * n as f64 * elem_bytes
}

/// Wire bytes per worker for one ring all-reduce of `n` gradient elements
/// across `k` workers, with the INT8-vs-FP32 element accounting the Fig. 9
/// timing model charges:
///
/// - FP32 payloads move 4-byte elements;
/// - quantized payloads move 1-byte elements **plus** one FP32 scale riding
///   along with each transferred chunk — `2·(k−1)` chunk sends per worker
///   (reduce-scatter + all-gather), 4 bytes each.
pub fn allreduce_payload_bytes(n: usize, k: usize, quantized: bool) -> f64 {
    allreduce_payload_bits(n, k, if quantized { Some(8) } else { None })
}

/// [`allreduce_payload_bytes`] generalized to sub-byte payload widths:
/// `bits = None` is FP32 (4-byte elements); `Some(b)` moves `b`-bit packed
/// elements (`b/8` bytes each — `Some(8)` is exactly the INT8 accounting,
/// and the 1-bit ternary grid charges two physical bits, see
/// [`crate::quant::packed_bits_per_elem`]) plus the per-chunk FP32 scales.
/// This is how quantized gradient exchange honours a non-INT8 run width
/// (`--bits 4 --quantize-grads` charges half-byte elements).
pub fn allreduce_payload_bits(n: usize, k: usize, bits: Option<u8>) -> f64 {
    let elem_bytes = match bits {
        None => 4.0,
        Some(b) => {
            assert!((1..=8).contains(&b), "payload width {b} unsupported (1..=8)");
            crate::quant::packed_bits_per_elem(b) as f64 / 8.0
        }
    };
    let scale_bytes =
        if bits.is_some() && k > 1 { 4.0 * 2.0 * (k as f64 - 1.0) } else { 0.0 };
    ring_transfer_bytes(n, k, elem_bytes) + scale_bytes
}

/// Number of point-to-point messages each worker sends in one ring
/// all-reduce across `k` workers (reduce-scatter + all-gather), which the
/// interconnect model charges a latency term per message.
pub fn ring_messages(k: usize) -> usize {
    2 * k.saturating_sub(1)
}

/// All-reduce (mean) of per-worker gradient vectors.
///
/// With `quantize_payload`, each worker's contribution is quantized to INT8
/// before "transfer" and dequantized at the receiver — numerically faithful
/// to what quantized gradient exchange does to the values (stochastic
/// rounding, per-tensor scale riding along with the payload).
pub fn ring_allreduce(grads: &mut [Vec<f32>], quantize_payload: bool, seed: u64) {
    ring_allreduce_bits(grads, if quantize_payload { Some(8) } else { None }, seed)
}

/// [`ring_allreduce`] generalized to an explicit wire width: `None` moves
/// FP32 payloads untouched, `Some(b)` quantizes each worker's contribution
/// to `b` bits before "transfer" (`Some(8)` is exactly the INT8 path).
pub fn ring_allreduce_bits(grads: &mut [Vec<f32>], bits: Option<u8>, seed: u64) {
    let _t = crate::obs::timed(crate::obs::keys::TIMED_ALLREDUCE_RING);
    let k = grads.len();
    if k == 0 {
        return;
    }
    let elems = (k * grads[0].len()) as u64;
    crate::obs::counter_add(crate::obs::keys::CTR_MULTIGPU_ALLREDUCE_ELEMS, elems);
    let n = grads[0].len();
    assert!(grads.iter().all(|g| g.len() == n), "ragged gradients");
    // Reduce: sum of (possibly wire-quantized) contributions.
    let mut sum = vec![0.0f32; n];
    for (w, g) in grads.iter().enumerate() {
        if let Some(b) = bits {
            let t = Dense::from_vec(&[n], g.clone());
            let q: QTensor = quantize(&t, b, Rounding::Stochastic { seed: seed ^ w as u64 });
            let deq = dequantize(&q);
            for (s, v) in sum.iter_mut().zip(deq.data()) {
                *s += v;
            }
        } else {
            for (s, v) in sum.iter_mut().zip(g.iter()) {
                *s += v;
            }
        }
    }
    let inv = 1.0 / k as f32;
    for s in sum.iter_mut() {
        *s *= inv;
    }
    // Broadcast.
    for g in grads.iter_mut() {
        g.copy_from_slice(&sum);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn fp32_allreduce_is_exact_mean() {
        let mut grads = vec![vec![1.0f32, 2.0, 3.0], vec![3.0, 2.0, 1.0]];
        ring_allreduce(&mut grads, false, 0);
        assert_eq!(grads[0], vec![2.0, 2.0, 2.0]);
        assert_eq!(grads[0], grads[1]);
    }

    #[test]
    fn quantized_allreduce_close_to_mean() {
        let a: Vec<f32> = (0..256).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..256).map(|i| (i as f32 * 0.11).cos()).collect();
        let want: Vec<f32> = a.iter().zip(&b).map(|(x, y)| (x + y) / 2.0).collect();
        let mut grads = vec![a, b];
        ring_allreduce(&mut grads, true, 7);
        let maxerr = grads[0].iter().zip(&want).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        // INT8 wire error is bounded by ~one grid step of the larger tensor.
        assert!(maxerr < 0.02, "maxerr {maxerr}");
        assert_eq!(grads[0], grads[1]);
    }

    #[test]
    fn transfer_bytes_formula() {
        assert_eq!(ring_transfer_bytes(100, 1, 4.0), 0.0);
        assert_eq!(ring_transfer_bytes(100, 2, 4.0), 400.0);
        // k→∞ approaches 2·n·bytes.
        assert!((ring_transfer_bytes(100, 100, 4.0) - 792.0).abs() < 1e-9);
    }

    #[test]
    fn payload_bytes_account_int8_vs_fp32() {
        // k=1: nothing moves either way.
        assert_eq!(allreduce_payload_bytes(1000, 1, false), 0.0);
        assert_eq!(allreduce_payload_bytes(1000, 1, true), 0.0);
        // k=4: fp32 = 2·3/4·n·4; int8 = 2·3/4·n·1 + 6 chunk scales.
        let fp = allreduce_payload_bytes(1000, 4, false);
        let q = allreduce_payload_bytes(1000, 4, true);
        assert_eq!(fp, 6000.0);
        assert_eq!(q, 1500.0 + 24.0);
        // Large gradients approach the full 4x payload ratio.
        let fp = allreduce_payload_bytes(4_000_000, 4, false);
        let q = allreduce_payload_bytes(4_000_000, 4, true);
        assert!(fp / q > 3.99, "{}", fp / q);
        assert_eq!(ring_messages(1), 0);
        assert_eq!(ring_messages(4), 6);
    }

    #[test]
    fn payload_bits_generalize_the_int8_accounting() {
        // Some(8) is exactly the bool path.
        assert_eq!(
            allreduce_payload_bits(1000, 4, Some(8)),
            allreduce_payload_bytes(1000, 4, true)
        );
        assert_eq!(allreduce_payload_bits(1000, 4, None), allreduce_payload_bytes(1000, 4, false));
        // Sub-byte widths shrink the element term but keep the scale term:
        // 4-bit elements move half the bytes of INT8.
        let q8 = allreduce_payload_bits(1000, 4, Some(8));
        let q4 = allreduce_payload_bits(1000, 4, Some(4));
        assert_eq!(q4, 750.0 + 24.0);
        assert!(q4 < q8);
        // The 1-bit ternary grid packs at two physical bits, same as 2-bit.
        assert_eq!(
            allreduce_payload_bits(1000, 4, Some(1)),
            allreduce_payload_bits(1000, 4, Some(2))
        );
        assert_eq!(allreduce_payload_bits(1000, 1, Some(4)), 0.0);
    }

    #[test]
    fn sub_byte_allreduce_still_agrees_and_approximates_the_mean() {
        let a: Vec<f32> = (0..128).map(|i| (i as f32 * 0.29).sin()).collect();
        let b: Vec<f32> = (0..128).map(|i| (i as f32 * 0.17).cos()).collect();
        let want: Vec<f32> = a.iter().zip(&b).map(|(x, y)| (x + y) / 2.0).collect();
        let mut grads = vec![a, b];
        ring_allreduce_bits(&mut grads, Some(4), 11);
        assert_eq!(grads[0], grads[1]);
        let maxerr =
            grads[0].iter().zip(&want).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        // 4-bit grid steps are ~1/7 of absmax; one step of slack per input.
        assert!(maxerr < 0.3, "maxerr {maxerr}");
    }

    #[test]
    fn prop_allreduce_workers_agree() {
        prop::check("allreduce agreement", 32, |g| {
            let k = g.usize_in(1, 6);
            let n = g.usize_in(1, 64);
            let mut grads: Vec<Vec<f32>> = (0..k).map(|_| g.f32_vec(n, -2.0, 2.0)).collect();
            let quant = g.bool(0.5);
            ring_allreduce(&mut grads, quant, g.u64());
            for w in 1..k {
                assert_eq!(grads[0], grads[w], "worker {w} disagrees");
            }
        });
    }
}
