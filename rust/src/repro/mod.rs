//! Reproduction of every table and figure in the paper's evaluation
//! (§4, Figs. 2 & 7–16, Tables 1–2). Each function regenerates one
//! artefact as a markdown [`Table`]; `tango repro <id>` prints it and
//! `tango repro all` prints the lot (EXPERIMENTS.md records a full run).
//!
//! Absolute numbers come from the CPU substrate and the analytical GPU
//! model (DESIGN.md §Substitutions); the assertions of shape — who wins,
//! by roughly what factor, where crossovers sit — are what the suite in
//! `rust/tests/repro_shapes.rs` checks.

mod accuracy;
mod primitives_bench;
mod speed;

pub use accuracy::{fig2, fig7};
pub use primitives_bench::{fig10, fig11, fig12, fig13, fig14, fig15, fig16, table2};
pub use speed::{fig8, fig9, table1};

use crate::metrics::Table;

/// Effort knob for the training-based repros.
#[derive(Debug, Clone, Copy)]
pub struct ReproConfig {
    /// Epochs for convergence/accuracy experiments.
    pub epochs: usize,
    /// Epochs for wall-clock speed experiments.
    pub speed_epochs: usize,
    /// RNG seed.
    pub seed: u64,
    /// Quick mode: smaller datasets for smoke-testing the harness.
    pub quick: bool,
}

impl Default for ReproConfig {
    fn default() -> Self {
        ReproConfig { epochs: 30, speed_epochs: 5, seed: 42, quick: false }
    }
}

/// Run one experiment by id ("fig2".."fig16", "table1", "table2", "all").
pub fn run(id: &str, cfg: &ReproConfig) -> crate::Result<Vec<Table>> {
    let tables: Vec<Table> = match id {
        "table1" => vec![table1(cfg)],
        "fig2" => fig2(cfg)?,
        "fig7" => fig7(cfg)?,
        "fig8" => vec![fig8(cfg)?],
        "fig9" => vec![fig9(cfg)?],
        "fig10" => vec![fig10(cfg)],
        "fig11" => fig11(cfg),
        "fig12" => vec![fig12(cfg)],
        "fig13" => fig13(cfg),
        "table2" => vec![table2(cfg)],
        "fig14" => vec![fig14(cfg)],
        "fig15" => vec![fig15(cfg)],
        "fig16" => fig16(cfg),
        "all" => {
            let mut all = Vec::new();
            for id in [
                "table1", "fig2", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13",
                "table2", "fig14", "fig15", "fig16",
            ] {
                all.extend(run(id, cfg)?);
            }
            all
        }
        other => anyhow::bail!("unknown experiment id '{other}'"),
    };
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_id_errors() {
        assert!(run("fig99", &ReproConfig::default()).is_err());
    }
}
