//! Table 1 (datasets), Fig. 8 (end-to-end training speed) and Fig. 9
//! (multi-GPU scaling).

use super::ReproConfig;
use crate::config::{ModelKind, TrainConfig};
use crate::coordinator::Trainer;
use crate::graph::datasets::{self, SPECS};
use crate::metrics::Table;
use crate::model::TrainMode;
use crate::multigpu::{run_data_parallel, Interconnect, MultiGpuConfig};

/// Table 1: paper dataset statistics next to our generated analogues.
pub fn table1(cfg: &ReproConfig) -> Table {
    let mut t = Table::new(
        "Table 1 — datasets (paper scale vs generated analogue)",
        &[
            "dataset",
            "paper nodes",
            "paper edges",
            "ours nodes",
            "ours edges",
            "avg degree",
            "task",
        ],
    );
    for spec in SPECS.iter() {
        let d = datasets::load(spec, cfg.seed);
        t.row(&[
            spec.name.into(),
            spec.paper_nodes.to_string(),
            spec.paper_edges.to_string(),
            d.graph.num_nodes.to_string(),
            d.graph.num_edges().to_string(),
            format!("{:.1}", d.graph.avg_degree()),
            format!("{:?}", spec.task),
        ]);
    }
    t
}

fn speed_cfg(cfg: &ReproConfig, model: ModelKind, dataset: &str, mode: TrainMode) -> TrainConfig {
    TrainConfig {
        model,
        dataset: dataset.into(),
        epochs: cfg.speed_epochs,
        lr: 0.05,
        hidden: if cfg.quick { 16 } else { 128 },
        heads: 4,
        layers: 2,
        mode,
        auto_bits: false,
        seed: cfg.seed,
        log_every: 0,
        ..Default::default()
    }
}

/// Fig. 8: end-to-end training time of Tango and EXACT relative to the
/// FP32 "DGL" baseline, GCN and GAT, all five datasets.
pub fn fig8(cfg: &ReproConfig) -> crate::Result<Table> {
    let mut t = Table::new(
        "Fig. 8 — training speedup over FP32 baseline (measured, CPU substrate)",
        &["model", "dataset", "fp32 s/epoch", "Tango speedup", "EXACT speedup"],
    );
    let datasets: Vec<&str> = if cfg.quick {
        vec!["tiny"]
    } else {
        vec!["ogbn-arxiv", "ogbn-products", "Pubmed", "DBLP", "Amazon"]
    };
    for model in [ModelKind::Gcn, ModelKind::Gat] {
        let name = if model == ModelKind::Gcn { "GCN" } else { "GAT" };
        for ds in &datasets {
            let time_of = |mode: TrainMode| -> crate::Result<f64> {
                let mut tr = Trainer::from_config(&speed_cfg(cfg, model, ds, mode))?;
                Ok(tr.run()?.wall_secs / cfg.speed_epochs as f64)
            };
            let fp = time_of(TrainMode::fp32())?;
            let tango = time_of(TrainMode::tango(8))?;
            let exact = time_of(TrainMode::exact(8))?;
            t.row(&[
                name.into(),
                (*ds).into(),
                format!("{fp:.3}"),
                format!("{:.2}x", fp / tango),
                format!("{:.2}x", fp / exact),
            ]);
        }
    }
    Ok(t)
}

/// Fig. 9: multi-GPU speedup of quantized vs FP32 gradient exchange as the
/// worker count grows (modelled PCIe, real computation + all-reduce).
pub fn fig9(cfg: &ReproConfig) -> crate::Result<Table> {
    let mut t = Table::new(
        "Fig. 9 — multi-GPU speedup (Tango vs FP32 all-reduce)",
        &["model", "workers", "fp32 epoch (s)", "tango epoch (s)", "speedup"],
    );
    let ds = if cfg.quick { "tiny" } else { "ogbn-arxiv" };
    let data = datasets::load_by_name_checked(ds, cfg.seed).map_err(|e| anyhow::anyhow!(e))?;
    let workers: Vec<usize> = if cfg.quick { vec![2, 3] } else { vec![2, 3, 4, 5, 6] };
    for model in [ModelKind::Gcn, ModelKind::Gat] {
        let name = if model == ModelKind::Gcn { "GCN" } else { "GAT" };
        for &k in &workers {
            let mk = |quant: bool| {
                let mut train = speed_cfg(
                    cfg,
                    model,
                    "ogbn-arxiv",
                    if quant { TrainMode::tango(8) } else { TrainMode::fp32() },
                );
                train.sampler.fanouts = vec![8, 8];
                train.sampler.batch_size = if cfg.quick { 64 } else { 512 };
                MultiGpuConfig {
                    train,
                    workers: k,
                    epochs: cfg.speed_epochs.min(3),
                    quantize_grads: quant,
                    interconnect: Interconnect::pcie3(),
                }
            };
            let fp = run_data_parallel(&mk(false), &data)?;
            let tg = run_data_parallel(&mk(true), &data)?;
            let fp_t = fp.total_time() / fp.epochs.len() as f64;
            let tg_t = tg.total_time() / tg.epochs.len() as f64;
            t.row(&[
                name.into(),
                k.to_string(),
                format!("{fp_t:.4}"),
                format!("{tg_t:.4}"),
                format!("{:.2}x", fp_t / tg_t),
            ]);
        }
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_all_five_datasets() {
        let t = table1(&ReproConfig { quick: true, ..Default::default() });
        assert_eq!(t.len(), 5);
    }

    #[test]
    fn fig8_quick_runs() {
        let cfg = ReproConfig { speed_epochs: 1, quick: true, ..Default::default() };
        let t = fig8(&cfg).unwrap();
        assert_eq!(t.len(), 2); // GCN + GAT on tiny
    }
}
