//! Primitive-level figures: Fig. 10 (caching), Fig. 11/12 (GEMM), Fig. 13 /
//! Table 2 / Fig. 14 (SPMM), Fig. 15 (SDDMM), Fig. 16 (bit widths).

use super::ReproConfig;
use crate::coordinator::adaptive::{modelled_costs, AdaptiveCosts};
use crate::graph::datasets::{self, SPECS};
use crate::graph::generators::random_features;
use crate::graph::{Csr, Incidence};
use crate::metrics::{bench_with_config, BenchConfig, Table, Traffic};
use crate::perfmodel::{gemm_time, profile_ratios, sddmm_time, GemmKind, SparseDtype, A100, V100};
use crate::primitives::{
    gemm_f32, incidence_spmm, qgemm, qgemm_prequantized, qsddmm_add, qsddmm_dot, sddmm_add,
    sddmm_dot, spmm_edge_aggregate_3mat, spmm_via_spmvs, spmm_edge_weighted, spmm_per_head,
};
use crate::quant::{quantize, Rounding};

fn bench_cfg(cfg: &ReproConfig) -> BenchConfig {
    if cfg.quick {
        BenchConfig { warmup_secs: 0.01, measure_secs: 0.05, min_samples: 2 }
    } else {
        BenchConfig { warmup_secs: 0.1, measure_secs: 0.4, min_samples: 5 }
    }
}

fn dataset_names(cfg: &ReproConfig) -> Vec<&'static str> {
    if cfg.quick {
        vec!["Pubmed"]
    } else {
        SPECS.iter().map(|s| s.name).collect()
    }
}

fn scaled_nodes(cfg: &ReproConfig, name: &str) -> usize {
    let n = datasets::spec(name).map(|s| s.num_nodes).unwrap_or(2000);
    if cfg.quick {
        n.min(2000)
    } else {
        n
    }
}

/// Fig. 10: GEMM with freshly quantized inputs vs cached quantized inputs
/// (the forward→backward reuse), D = 128 and 256.
pub fn fig10(cfg: &ReproConfig) -> Table {
    let mut t = Table::new(
        "Fig. 10 — speedup from caching quantized tensors (qGEMM, measured)",
        &["dataset", "D", "fresh (ms)", "cached (ms)", "speedup"],
    );
    let bc = bench_cfg(cfg);
    for ds in dataset_names(cfg) {
        let m = scaled_nodes(cfg, ds);
        for &d in &[128usize, 256] {
            let a = random_features(m, d, 1);
            let b = random_features(d, d, 2);
            let fresh = bench_with_config("fresh", bc, &mut || qgemm(&a, &b, 8, Rounding::Nearest));
            let qa = quantize(&a, 8, Rounding::Nearest);
            let qb = quantize(&b, 8, Rounding::Nearest);
            let cached =
                bench_with_config("cached", bc, &mut || qgemm_prequantized(&qa, &qb, 8));
            t.row(&[
                ds.into(),
                d.to_string(),
                format!("{:.2}", fresh.mean * 1e3),
                format!("{:.2}", cached.mean * 1e3),
                format!("{:.2}x", fresh.mean / cached.mean),
            ]);
        }
    }
    t
}

/// Fig. 11: (a) measured CPU qGEMM vs FP32 GEMM; (b) the V100/A100 cost
/// model's projections for the paper's hardware.
pub fn fig11(cfg: &ReproConfig) -> Vec<Table> {
    let bc = bench_cfg(cfg);
    let mut a = Table::new(
        "Fig. 11a — quantized GEMM vs FP32 GEMM (measured, CPU substrate)",
        &["dataset", "D", "fp32 (ms)", "int8 (ms)", "speedup"],
    );
    for ds in dataset_names(cfg) {
        let m = scaled_nodes(cfg, ds);
        for &d in &[256usize, 512] {
            let x = random_features(m, d, 3);
            let w = random_features(d, d, 4);
            let f = bench_with_config("f32", bc, &mut || gemm_f32(&x, &w));
            let q = bench_with_config("q8", bc, &mut || qgemm(&x, &w, 8, Rounding::Nearest));
            a.row(&[
                ds.into(),
                d.to_string(),
                format!("{:.2}", f.mean * 1e3),
                format!("{:.2}", q.mean * 1e3),
                format!("{:.2}x", f.mean / q.mean),
            ]);
        }
    }
    let mut b = Table::new(
        "Fig. 11 (model) — projected GEMM speedups on the paper's GPUs",
        &["GPU", "D", "baseline", "Tango", "speedup"],
    );
    for &d in &[256usize, 512] {
        let m = 169_343; // ogbn-arxiv nodes, the paper's M
        let t32 = gemm_time(&V100, m, d, d, GemmKind::Fp32Cuda, false);
        let t8 = gemm_time(&V100, m, d, d, GemmKind::Int8Dp4a, false);
        b.row(&["V100".into(), d.to_string(), "cuBLAS FP32".into(), "INT8 DP4A".into(), format!("{:.2}x", t32 / t8)]);
        let t16 = gemm_time(&A100, m, d, d, GemmKind::Fp16Tensor, false);
        let t8tc = gemm_time(&A100, m, d, d, GemmKind::Int8Tensor, false);
        b.row(&["A100".into(), d.to_string(), "FP16 TC".into(), "INT8 TC".into(), format!("{:.2}x", t16 / t8tc)]);
    }
    vec![a, b]
}

/// Fig. 12: modelled profiling ratios of quantized GEMM vs cuBLAS FP32.
pub fn fig12(_cfg: &ReproConfig) -> Table {
    let mut t = Table::new(
        "Fig. 12 — qGEMM profiling ratios vs cuBLAS FP32 (V100 model)",
        &["D", "compute throughput", "memory throughput", "IPC", "# instructions"],
    );
    for &d in &[128usize, 256, 512] {
        let p = profile_ratios(&V100, 169_343, d, d);
        t.row(&[
            d.to_string(),
            format!("{:.2}x", p.compute_throughput_ratio),
            format!("{:.2}x", p.memory_throughput_ratio),
            format!("{:.0}%", p.ipc_ratio * 100.0),
            format!("{:.0}%", p.instruction_ratio * 100.0),
        ]);
    }
    t
}

/// Fig. 13: (a) incidence-matrix SPMM vs the DGL 3-matrix kernel over edge
/// feature sizes; (b) per-head split vs the native kernel for multi-head
/// attention shapes.
pub fn fig13(cfg: &ReproConfig) -> Vec<Table> {
    let bc = bench_cfg(cfg);
    let mut a = Table::new(
        "Fig. 13a — incidence SPMM vs 3-matrix SPMM (measured)",
        &["dataset", "edge feat", "3-mat (ms)", "incidence (ms)", "speedup"],
    );
    let feats: Vec<usize> = if cfg.quick { vec![8] } else { vec![4, 8, 12, 16, 20] };
    for ds in dataset_names(cfg) {
        let data = datasets::load_by_name(if cfg.quick { "Pubmed" } else { ds }, cfg.seed);
        let csr = Csr::from_coo(&data.graph);
        let inc = Incidence::from_csr(&csr);
        for &f in &feats {
            let ef = random_features(csr.num_edges, f, 5);
            let base = bench_with_config("3mat", bc, &mut || spmm_edge_aggregate_3mat(&csr, &ef));
            let ours = bench_with_config("inc", bc, &mut || incidence_spmm(&inc, &ef));
            a.row(&[
                ds.into(),
                f.to_string(),
                format!("{:.2}", base.mean * 1e3),
                format!("{:.2}", ours.mean * 1e3),
                format!("{:.2}x", base.mean / ours.mean),
            ]);
        }
    }
    let mut b = Table::new(
        "Fig. 13b — per-head split SPMM vs native 3-matrix (measured)",
        &["dataset", "heads", "D", "native (ms)", "split (ms)", "speedup"],
    );
    let head_cfgs: Vec<(usize, usize)> = if cfg.quick { vec![(4, 8)] } else { vec![(2, 16), (4, 16), (8, 16)] };
    for ds in dataset_names(cfg) {
        let data = datasets::load_by_name(if cfg.quick { "Pubmed" } else { ds }, cfg.seed);
        let csr = Csr::from_coo(&data.graph);
        for &(h, d) in &head_cfgs {
            let alpha = random_features(csr.num_edges, h, 6);
            let x = random_features(csr.num_nodes, h * d, 7);
            let native = bench_with_config("native", bc, &mut || spmm_edge_weighted(&csr, &alpha, &x, h));
            let split = bench_with_config("split", bc, &mut || spmm_per_head(&csr, &alpha, &x, h));
            b.row(&[
                ds.into(),
                h.to_string(),
                d.to_string(),
                format!("{:.2}", native.mean * 1e3),
                format!("{:.2}", split.mean * 1e3),
                format!("{:.2}x", native.mean / split.mean),
            ]);
        }
    }
    vec![a, b]
}

/// Table 2: achieved memory throughput of incidence SPMM vs the 3-matrix
/// baseline at edge-feature size 16 (bytes moved / measured time).
pub fn table2(cfg: &ReproConfig) -> Table {
    let bc = bench_cfg(cfg);
    let mut t = Table::new(
        "Table 2 — achieved memory throughput, edge aggregation (feat 16)",
        &["dataset", "ours (GB/s)", "baseline (GB/s)", "ratio"],
    );
    let f = 16usize;
    for ds in dataset_names(cfg) {
        let data = datasets::load_by_name(if cfg.quick { "Pubmed" } else { ds }, cfg.seed);
        let csr = Csr::from_coo(&data.graph);
        let inc = Incidence::from_csr(&csr);
        let ef = random_features(csr.num_edges, f, 8);
        let ours = bench_with_config("inc", bc, &mut || incidence_spmm(&inc, &ef));
        let base = bench_with_config("3mat", bc, &mut || spmm_edge_aggregate_3mat(&csr, &ef));
        // Useful bytes: edge features read once + output written once
        // (+ the redundant all-ones matrix for the baseline).
        let useful = Traffic {
            read_bytes: (csr.num_edges * f * 4 + csr.num_edges * 8) as u64,
            write_bytes: (csr.num_nodes * f * 4) as u64,
        };
        let base_traffic = Traffic {
            read_bytes: useful.read_bytes + (csr.num_edges * f * 4) as u64, // ones matrix
            write_bytes: useful.write_bytes,
        };
        let g_ours = useful.gbps(ours.mean);
        let g_base = base_traffic.gbps(base.mean);
        t.row(&[
            ds.into(),
            format!("{g_ours:.2}"),
            format!("{g_base:.2}"),
            format!("{:.2}x", g_ours / g_base),
        ]);
    }
    t
}

/// Fig. 14: the many-SpMV transform vs the native kernel as the edge
/// feature dimension grows (measured + the adaptive model's crossover).
pub fn fig14(cfg: &ReproConfig) -> Table {
    let bc = bench_cfg(cfg);
    let mut t = Table::new(
        "Fig. 14 — many-SpMV transform vs native SPMM on ogbn-arxiv (measured + model)",
        &["edge feat", "native (ms)", "spmv xN (ms)", "measured speedup", "model speedup (V100)"],
    );
    let data = datasets::load_by_name(if cfg.quick { "Pubmed" } else { "ogbn-arxiv" }, cfg.seed);
    let csr = Csr::from_coo(&data.graph);
    let feats: Vec<usize> = if cfg.quick { vec![2, 6] } else { vec![2, 4, 6, 8, 10, 12] };
    let costs = AdaptiveCosts::default();
    for &f in &feats {
        let alpha = random_features(csr.num_edges, 1, 9);
        let x = random_features(csr.num_nodes, f, 10);
        let native = bench_with_config("native", bc, &mut || spmm_edge_weighted(&csr, &alpha, &x, 1));
        let spmv = bench_with_config("spmv", bc, &mut || spmm_via_spmvs(&csr, &alpha, &x, 1));
        let model = modelled_costs(1_166_243, 1, f, &costs);
        t.row(&[
            f.to_string(),
            format!("{:.2}", native.mean * 1e3),
            format!("{:.2}", spmv.mean * 1e3),
            format!("{:.2}x", native.mean / spmv.mean),
            format!("{:.2}x", model[0].1 / model[2].1),
        ]);
    }
    t
}

/// Fig. 15: quantized SDDMM (add / dot) vs the FP32 kernels, features (4,64).
pub fn fig15(cfg: &ReproConfig) -> Table {
    let bc = bench_cfg(cfg);
    let mut t = Table::new(
        "Fig. 15 — quantized SDDMM vs FP32 (measured, heads=4, D=64)",
        &["dataset", "add f32 (ms)", "add q8 (ms)", "add speedup", "dot f32 (ms)", "dot q8 (ms)", "dot speedup"],
    );
    let (heads, d) = (4usize, 64usize);
    for ds in dataset_names(cfg) {
        let data = datasets::load_by_name(if cfg.quick { "Pubmed" } else { ds }, cfg.seed);
        let coo = &data.graph;
        let n = coo.num_nodes;
        let s = random_features(n, heads, 11);
        let dd = random_features(n, heads, 12);
        let qs = quantize(&s, 8, Rounding::Nearest);
        let qd = quantize(&dd, 8, Rounding::Nearest);
        let add_f = bench_with_config("addf", bc, &mut || sddmm_add(coo, &s, &dd));
        let add_q = bench_with_config("addq", bc, &mut || qsddmm_add(coo, &qs, &qd));
        let a = random_features(n, heads * d, 13);
        let b = random_features(n, heads * d, 14);
        let qa = quantize(&a, 8, Rounding::Nearest);
        let qb = quantize(&b, 8, Rounding::Nearest);
        let dot_f = bench_with_config("dotf", bc, &mut || sddmm_dot(coo, &a, &b, heads));
        let dot_q = bench_with_config("dotq", bc, &mut || qsddmm_dot(coo, &qa, &qb, heads));
        t.row(&[
            ds.into(),
            format!("{:.2}", add_f.mean * 1e3),
            format!("{:.2}", add_q.mean * 1e3),
            format!("{:.2}x", add_f.mean / add_q.mean),
            format!("{:.2}", dot_f.mean * 1e3),
            format!("{:.2}", dot_q.mean * 1e3),
            format!("{:.2}x", dot_f.mean / dot_q.mean),
        ]);
    }
    t
}

/// Fig. 16: (a) INT4 SDDMM vs FP32 (measured INT4-range + modelled packed
/// traffic); (b) INT8/INT4 tensor-core GEMM on the A100 model.
pub fn fig16(cfg: &ReproConfig) -> Vec<Table> {
    let bc = bench_cfg(cfg);
    let mut a = Table::new(
        "Fig. 16a — INT4 SDDMM vs FP32 (measured int4-range; packed traffic modelled)",
        &["dataset", "add speedup (int4)", "dot speedup (int4)", "model add (V100)", "model dot (V100)"],
    );
    let (heads, d) = (4usize, 64usize);
    for ds in dataset_names(cfg) {
        let data = datasets::load_by_name(if cfg.quick { "Pubmed" } else { ds }, cfg.seed);
        let coo = &data.graph;
        let n = coo.num_nodes;
        let s = random_features(n, heads, 15);
        let dd = random_features(n, heads, 16);
        let q4s = quantize(&s, 4, Rounding::Nearest);
        let q4d = quantize(&dd, 4, Rounding::Nearest);
        let add_f = bench_with_config("addf", bc, &mut || sddmm_add(coo, &s, &dd));
        let add_q = bench_with_config("addq4", bc, &mut || qsddmm_add(coo, &q4s, &q4d));
        let av = random_features(n, heads * d, 17);
        let bv = random_features(n, heads * d, 18);
        let q4a = quantize(&av, 4, Rounding::Nearest);
        let q4b = quantize(&bv, 4, Rounding::Nearest);
        let dot_f = bench_with_config("dotf", bc, &mut || sddmm_dot(coo, &av, &bv, heads));
        let dot_q = bench_with_config("dotq4", bc, &mut || qsddmm_dot(coo, &q4a, &q4b, heads));
        let e = coo.num_edges();
        let m_add_f = sddmm_time(&V100, n, e, heads, SparseDtype::F32);
        let m_add_4 = sddmm_time(&V100, n, e, heads, SparseDtype::I4);
        let m_dot_f = sddmm_time(&V100, n, e, heads * d, SparseDtype::F32);
        let m_dot_4 = sddmm_time(&V100, n, e, heads * d, SparseDtype::I4);
        a.row(&[
            ds.into(),
            format!("{:.2}x", add_f.mean / add_q.mean),
            format!("{:.2}x", dot_f.mean / dot_q.mean),
            format!("{:.2}x", m_add_f / m_add_4),
            format!("{:.2}x", m_dot_f / m_dot_4),
        ]);
    }
    let mut b = Table::new(
        "Fig. 16b — INT8/INT4 tensor-core GEMM vs cuBLAS FP32 (A100 model)",
        &["D", "INT8 speedup", "INT4 speedup"],
    );
    for &dd in &[256usize, 512] {
        let m = 169_343;
        let t32 = gemm_time(&A100, m, dd, dd, GemmKind::Fp32Cuda, false);
        let t8 = gemm_time(&A100, m, dd, dd, GemmKind::Int8Tensor, false);
        let t4 = gemm_time(&A100, m, dd, dd, GemmKind::Int4Tensor, false);
        b.row(&[dd.to_string(), format!("{:.1}x", t32 / t8), format!("{:.1}x", t32 / t4)]);
    }
    vec![a, b]
}

/// Fig. 11/13-16 model-only sanity used by tests.
#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> ReproConfig {
        ReproConfig { epochs: 2, speed_epochs: 1, seed: 1, quick: true }
    }

    #[test]
    fn fig10_rows() {
        assert_eq!(fig10(&quick()).len(), 2);
    }

    #[test]
    fn fig12_rows() {
        assert_eq!(fig12(&quick()).len(), 3);
    }

    #[test]
    fn fig14_rows() {
        assert_eq!(fig14(&quick()).len(), 2);
    }
}
