//! Fig. 2 (Error_X threshold / bit derivation) and Fig. 7 (convergence
//! under the accuracy-rule ablations).

use super::ReproConfig;
use crate::config::{ModelKind, TrainConfig};
use crate::coordinator::Trainer;
use crate::graph::datasets;
use crate::metrics::Table;
use crate::model::TrainMode;
use crate::quant::{derive_bits, DEFAULT_ERROR_TARGET};

fn nc_datasets(cfg: &ReproConfig) -> Vec<&'static str> {
    if cfg.quick {
        vec!["tiny"]
    } else {
        vec!["ogbn-arxiv", "Pubmed", "ogbn-products"]
    }
}

fn all_datasets(cfg: &ReproConfig) -> Vec<&'static str> {
    if cfg.quick {
        vec!["tiny"]
    } else {
        vec!["ogbn-arxiv", "ogbn-products", "Pubmed", "DBLP", "Amazon"]
    }
}

fn base_train(cfg: &ReproConfig, model: ModelKind, dataset: &str, mode: TrainMode) -> TrainConfig {
    TrainConfig {
        model,
        dataset: dataset.into(),
        epochs: cfg.epochs,
        lr: 0.1,
        hidden: if cfg.quick { 16 } else { 64 },
        heads: 4,
        layers: 2,
        mode,
        auto_bits: false,
        seed: cfg.seed,
        log_every: 0,
        ..Default::default()
    }
}

/// Fig. 2: (a) accuracy at bit widths chosen for different `Error_X`
/// targets; (b) the bit width the rule derives per dataset at 0.3.
pub fn fig2(cfg: &ReproConfig) -> crate::Result<Vec<Table>> {
    let mut a = Table::new(
        "Fig. 2a — eval accuracy vs Error_X target (GCN)",
        &["dataset", "target", "derived bits", "accuracy", "fp32 accuracy"],
    );
    let mut b = Table::new(
        "Fig. 2b — Error_X bit sweep (first-layer output, target 0.3)",
        &["dataset", "bits=2", "3", "4", "5", "6", "7", "8", "chosen"],
    );
    for ds in nc_datasets(cfg) {
        // FP32 reference accuracy.
        let mut fp = Trainer::from_config(&base_train(cfg, ModelKind::Gcn, ds, TrainMode::fp32()))?;
        let fp_acc = fp.run()?.final_eval;
        // The rule's probe tensor.
        let data = datasets::load_by_name_checked(ds, cfg.seed).map_err(|e| anyhow::anyhow!(e))?;
        let probe = {
            let t = Trainer::from_config(&base_train(cfg, ModelKind::Gcn, ds, TrainMode::fp32()))?;
            let _ = t; // trainer builds the model; re-derive via a fresh model below
            let gcn = crate::model::GcnModel::new(
                crate::model::GcnConfig {
                    in_dim: data.features.cols(),
                    hidden: if cfg.quick { 16 } else { 64 },
                    out_dim: data.num_classes,
                    layers: 2,
                    mode: TrainMode::fp32(),
                },
                &data.graph,
                cfg.seed,
            );
            gcn.first_layer_output(&data.features)
        };
        for &target in &[0.1f32, 0.3, 0.5, 0.7] {
            let d = derive_bits(&probe, target);
            let mut t =
                Trainer::from_config(&base_train(
                    cfg,
                    ModelKind::Gcn,
                    ds,
                    TrainMode::tango(d.bits),
                ))?;
            let acc = t.run()?.final_eval;
            a.row(&[
                ds.into(),
                format!("{target:.1}"),
                d.bits.to_string(),
                format!("{acc:.4}"),
                format!("{fp_acc:.4}"),
            ]);
        }
        let d = derive_bits(&probe, DEFAULT_ERROR_TARGET);
        let mut row = vec![ds.to_string()];
        row.extend(d.sweep.iter().map(|(_, e)| format!("{e:.3}")));
        row.push(d.bits.to_string());
        b.row(&row);
    }
    Ok(vec![a, b])
}

/// Fig. 7: convergence of Tango vs Test1 (quantized pre-softmax layer) vs
/// Test2 (nearest rounding) vs the FP32 baseline.
pub fn fig7(cfg: &ReproConfig) -> crate::Result<Vec<Table>> {
    let mut tables = Vec::new();
    for model in [ModelKind::Gcn, ModelKind::Gat] {
        let name = if model == ModelKind::Gcn { "GCN" } else { "GAT" };
        let mut t = Table::new(
            &format!("Fig. 7 — {name} convergence (final eval; epochs-to-converge)"),
            &["dataset", "fp32 (DGL)", "Tango", "Test1 (quant pre-softmax)", "Test2 (nearest)"],
        );
        for ds in all_datasets(cfg) {
            let mut cells = vec![ds.to_string()];
            for mode in [
                TrainMode::fp32(),
                TrainMode::tango(8),
                TrainMode::tango_test1(8),
                TrainMode::tango_test2(8),
            ] {
                let mut tr = Trainer::from_config(&base_train(cfg, model, ds, mode))?;
                let r = tr.run()?;
                cells.push(format!("{:.4} ({}ep)", r.final_eval, r.epochs_to_converge));
            }
            t.row(&cells);
        }
        tables.push(t);
    }
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_quick_produces_rows() {
        let cfg = ReproConfig { epochs: 5, quick: true, ..Default::default() };
        let tables = fig2(&cfg).unwrap();
        assert_eq!(tables.len(), 2);
        assert_eq!(tables[0].len(), 4); // four targets × one quick dataset
        assert_eq!(tables[1].len(), 1);
    }

    #[test]
    fn fig7_quick_produces_rows() {
        let cfg = ReproConfig { epochs: 5, quick: true, ..Default::default() };
        let tables = fig7(&cfg).unwrap();
        assert_eq!(tables.len(), 2);
        assert_eq!(tables[0].len(), 1);
    }
}
