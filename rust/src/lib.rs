//! # Tango-RS
//!
//! A reproduction of **"Tango: rethinking quantization for graph neural
//! network training on GPUs"** (Chen et al., SC '23) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! Tango is a quantized GNN *training* system: symmetric, tensor-granularity,
//! dynamic INT8/INT4 quantization applied to the three primitives that
//! dominate GNN training — GEMM, SPMM and SDDMM — together with lightweight
//! accuracy rules (stochastic rounding, an `Error_X` bit-derivation metric,
//! full-precision weight updates and a full-precision layer before Softmax)
//! so that quantized training is *faster* than FP32 training at <1% accuracy
//! loss.
//!
//! ## Layer map
//!
//! - **Layer 3 (this crate)** — the coordinator: graph substrate, quantized
//!   primitives, GCN/GAT models behind the
//!   [`GnnModel`](model::GnnModel) trait with **one** explicit
//!   forward/backward — the sampled-block path; a full-graph epoch is the
//!   block path over identity blocks
//!   ([`Block::identity`](sampler::Block::identity)) — plus
//!   [`TaskHead`](model::TaskHead)s for softmax-CE node classification and
//!   dot-product link prediction, the inter-primitive quantized-tensor
//!   cache and reuse detection, adaptive kernel selection, the mini-batch
//!   neighbor-sampling subsystem ([`sampler`]: layered fanout sampling —
//!   uniform or degree-biased, MFG block extraction, edge-seeded LP
//!   batches with seed-edge exclusion, bounded quantized feature
//!   gathering, and the pipelined batch-prefetch engine — the paper's
//!   §4.2 overlap: a producer thread runs sampling + quantized gather
//!   `prefetch` batches ahead of the training step, bit-identical to the
//!   sequential sweep), the degree-aware mixed-precision policy subsystem
//!   ([`policy`]: degree buckets × per-bucket bit widths with per-bucket
//!   static scales and gather-traffic accounting — the Degree-Quant/BiFeat
//!   rule that keeps hot nodes at high precision and compresses the cold
//!   tail, `--degree-buckets 8,64 --bucket-bits 8,6,4`), true bit-packed
//!   sub-byte storage and compute ([`quant::pack`]: LSB-first bitstreams
//!   behind [`QuantRows`](sampler::QuantRows);
//!   [`primitives::packed`]: SPMM/QGEMM kernels that consume the packed
//!   payload directly, dispatched per call site through the
//!   [`PrimitiveBackend`](primitives::PrimitiveBackend) seam —
//!   `--packed-compute`, bit-identical numerics to the dequantize path,
//!   and the same seam a future GPU/Pallas artifact dispatch plugs into),
//!   a multi-worker
//!   data-parallel simulator whose workers train persistent
//!   [`AnyModel`](model::AnyModel)s on the same sampler `Block` pipeline
//!   for both tasks (per-worker sampling streams *and* per-worker prefetch
//!   producers with measured overlap, one process-wide quantized feature
//!   store, per-step quantized ring all-reduce over a modelled PCIe
//!   interconnect), the observability layer ([`obs`]: zero-dep hierarchical
//!   spans, counters/gauges, log-bucketed p50/p95/p99 latency histograms
//!   and the `--metrics-out` JSON run artifact — a true no-op when disabled
//!   via `TANGO_TRACE=0`, so bit-identity and bench numbers are
//!   unaffected), an analytical GPU cost model, and the PJRT runtime
//!   that executes jax-lowered artifacts. Long runs are fault-tolerant:
//!   the checkpoint subsystem ([`ckpt`]: the versioned `tango-ckpt/v1`
//!   artifact — master weights, optimizer state, epoch/batch cursor and
//!   RNG stream descriptors as hex bit patterns, written atomically every
//!   `--ckpt-every` steps and restored with `--resume`, bit-identical to
//!   the uninterrupted trace) pairs with a deterministic seeded
//!   fault-injection harness ([`fault`]: producer panics, worker step
//!   failures, all-reduce link drops and lock poisoning scheduled by
//!   global step under `--inject-faults`, recovered via bounded retries
//!   with simulated exponential backoff, skip-straggler degradation and
//!   checkpoint replay — every recovery counted in the metrics artifact's
//!   `fault` section). The obs layer also records the event *timeline*:
//!   per-thread bounded rings of `B/E/i/C` events on a run-relative
//!   clock, exported via `--trace-out` as Perfetto-loadable Chrome trace
//!   JSON (`tango-trace/v1`) that shows the producer-thread prefetch
//!   overlapping compute, with a fault *flight recorder*
//!   (`--flight-recorder N`) dumping the last-N events per thread on
//!   every recovery; and the [`perf`] subsystem diffs two run/bench
//!   artifacts key-by-key (`tango perf diff`, schema `tango-perf/v1`) as
//!   the deterministic CI regression gate over those numbers.
//! - **Static analysis** — [`audit`] and the `tango_audit` binary: a
//!   zero-dependency, repo-specific pass over `rust/src/**` that enforces
//!   the invariants the compiler cannot see — determinism (no stray
//!   clocks, no hash-order iteration; rule D1), the central obs-key
//!   registry ([`obs::keys`]; rule O1), config-surface symmetry between
//!   `--flags`, TOML keys and `configs/*.toml` (rule C1), no panic
//!   paths in library code (rule P1), and atomic persistence — every
//!   run-artifact write goes through `util::fsio::write_atomic` so a
//!   crash never leaves a truncated checkpoint or metrics file (rule
//!   W1) — with vetted exceptions in `audit.allow.toml`. CI runs it as
//!   a blocking job.
//! - **Layer 2 (`python/compile/model.py`)** — GCN/GAT forward/backward in
//!   JAX, AOT-lowered to HLO text under `artifacts/`.
//! - **Layer 1 (`python/compile/kernels/`)** — Pallas kernels (quantize,
//!   quantized GEMM, SPMM, SDDMM) called by Layer 2.
//!
//! Python never runs at training time; the Rust binary is self-contained
//! once `make artifacts` has produced the HLO text files.
//!
//! ## Quickstart
//!
//! ```no_run
//! use tango::config::TrainConfig;
//! use tango::coordinator::Trainer;
//!
//! let cfg = TrainConfig::quickstart();
//! let mut trainer = Trainer::from_config(&cfg).unwrap();
//! let report = trainer.run().unwrap();
//! println!("final accuracy: {:.4}", report.final_eval);
//! ```

pub mod audit;
pub mod ckpt;
pub mod config;
pub mod coordinator;
pub mod fault;
pub mod graph;
pub mod metrics;
pub mod model;
pub mod multigpu;
pub mod obs;
pub mod perf;
pub mod perfmodel;
pub mod policy;
pub mod primitives;
pub mod quant;
pub mod repro;
pub mod runtime;
pub mod sampler;
pub mod tensor;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
