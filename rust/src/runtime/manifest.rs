//! Artifact manifest (`artifacts/manifest.json`, written by `aot.py`).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// One input's declared shape/dtype.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InputSpec {
    /// Dimensions (empty = scalar).
    pub shape: Vec<usize>,
    /// "f32" | "i32" | "i8".
    pub dtype: String,
}

/// One artifact's manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Registry name.
    pub name: String,
    /// HLO text file (relative to the artifact dir).
    pub file: String,
    /// Human description.
    pub description: String,
    /// Input specs, positional.
    pub inputs: Vec<InputSpec>,
    /// Number of tuple outputs.
    pub num_outputs: usize,
    /// Named problem sizes (n, p, f, h, c, ...).
    pub sizes: BTreeMap<String, usize>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Format version.
    pub version: usize,
    /// All artifacts.
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Parse from JSON text.
    pub fn parse(text: &str) -> crate::Result<Manifest> {
        let j = Json::parse(text)?;
        let version = j.get("version").and_then(Json::as_usize).unwrap_or(0);
        let mut artifacts = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'artifacts'"))?
        {
            let get_str = |k: &str| -> crate::Result<String> {
                Ok(a.get(k)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("artifact missing '{k}'"))?
                    .to_string())
            };
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(|i| -> crate::Result<InputSpec> {
                    let shape = i
                        .get("shape")
                        .and_then(Json::as_arr)
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(Json::as_usize)
                        .collect();
                    let dtype = i
                        .get("dtype")
                        .and_then(Json::as_str)
                        .unwrap_or("f32")
                        .to_string();
                    Ok(InputSpec { shape, dtype })
                })
                .collect::<crate::Result<Vec<_>>>()?;
            let mut sizes = BTreeMap::new();
            if let Some(Json::Obj(m)) = a.get("sizes") {
                for (k, v) in m {
                    if let Some(n) = v.as_usize() {
                        sizes.insert(k.clone(), n);
                    }
                }
            }
            artifacts.push(ArtifactSpec {
                name: get_str("name")?,
                file: get_str("file")?,
                description: get_str("description").unwrap_or_default(),
                inputs,
                num_outputs: a.get("num_outputs").and_then(Json::as_usize).unwrap_or(1),
                sizes,
            });
        }
        Ok(Manifest { version, artifacts })
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> crate::Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| anyhow::anyhow!("{}: {e} (run `make artifacts`)", path.as_ref().display()))?;
        Self::parse(&text)
    }

    /// Find an artifact by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{"version": 1, "artifacts": [
      {"name": "gcn_train_step", "file": "gcn_train_step.hlo.txt",
       "description": "step", "inputs": [{"shape": [8, 4], "dtype": "f32"},
       {"shape": [], "dtype": "f32"}], "num_outputs": 3,
       "sizes": {"n": 8, "p": 2}}]}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.version, 1);
        assert_eq!(m.artifacts.len(), 1);
        let a = m.get("gcn_train_step").unwrap();
        assert_eq!(a.file, "gcn_train_step.hlo.txt");
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![8, 4]);
        assert!(a.inputs[1].shape.is_empty());
        assert_eq!(a.num_outputs, 3);
        assert_eq!(a.sizes["p"], 2);
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse(r#"{"version": 1}"#).is_err());
        assert!(Manifest::parse(r#"{"artifacts": [{"file": "x"}], "version": 1}"#).is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        // Opportunistic: if `make artifacts` has run, parse the real thing.
        if let Ok(m) = Manifest::load("artifacts/manifest.json") {
            assert!(m.get("gcn_train_step").is_some());
            assert!(m.get("qgemm8").is_some());
        }
    }
}
