//! PJRT runtime: loads the jax-lowered HLO text artifacts produced by
//! `make artifacts` and executes them on the XLA CPU client — the Layer-3 ↔
//! Layer-1/2 boundary. Python never runs here; the Rust binary is
//! self-contained once `artifacts/` exists.
//!
//! Interchange format is HLO *text* (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): jax ≥ 0.5 emits protos with 64-bit ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.

mod manifest;

pub use manifest::{ArtifactSpec, InputSpec, Manifest};

use crate::tensor::Dense;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A loaded, compiled artifact ready to execute.
pub struct Executable {
    /// Its manifest entry.
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// Values crossing the runtime boundary.
#[derive(Debug, Clone)]
pub enum Value {
    /// f32 tensor.
    F32(Dense<f32>),
    /// i32 tensor.
    I32(Dense<i32>),
    /// i8 tensor.
    I8(Dense<i8>),
    /// f32 scalar.
    ScalarF32(f32),
}

impl Value {
    fn to_literal(&self) -> crate::Result<xla::Literal> {
        let lit = match self {
            Value::F32(t) => {
                let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(t.data()).reshape(&dims)?
            }
            Value::I32(t) => {
                let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(t.data()).reshape(&dims)?
            }
            Value::I8(t) => {
                // i8 is not a crate NativeType; build from raw bytes.
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.data().len())
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S8,
                    t.shape(),
                    bytes,
                )?
            }
            Value::ScalarF32(v) => xla::Literal::from(*v),
        };
        Ok(lit)
    }

    /// Interpret as an f32 tensor (errors otherwise).
    pub fn as_f32(&self) -> crate::Result<&Dense<f32>> {
        match self {
            Value::F32(t) => Ok(t),
            other => anyhow::bail!("expected f32 tensor, got {other:?}"),
        }
    }

    /// Interpret as an f32 scalar (rank-0 or single-element).
    pub fn as_scalar_f32(&self) -> crate::Result<f32> {
        match self {
            Value::ScalarF32(v) => Ok(*v),
            Value::F32(t) if t.len() == 1 => Ok(t.data()[0]),
            other => anyhow::bail!("expected f32 scalar, got {other:?}"),
        }
    }
}

fn literal_to_value(lit: &xla::Literal) -> crate::Result<Value> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    Ok(match shape.ty() {
        xla::ElementType::F32 => {
            let data = lit.to_vec::<f32>()?;
            if dims.is_empty() {
                Value::ScalarF32(data[0])
            } else {
                Value::F32(Dense::from_vec(&dims, data))
            }
        }
        xla::ElementType::S32 => Value::I32(Dense::from_vec(&dims, lit.to_vec::<i32>()?)),
        xla::ElementType::S8 => Value::I8(Dense::from_vec(&dims, lit.to_vec::<i8>()?)),
        other => anyhow::bail!("unsupported output element type {other:?}"),
    })
}

impl Executable {
    /// Execute with positional inputs; returns the flattened tuple outputs.
    pub fn run(&self, inputs: &[Value]) -> crate::Result<Vec<Value>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|v| v.to_literal()).collect::<crate::Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        let parts = result.to_tuple()?;
        parts.iter().map(literal_to_value).collect()
    }
}

/// The artifact registry: manifest + PJRT client + lazily compiled
/// executables.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    /// Parsed manifest.
    pub manifest: Manifest,
    compiled: HashMap<String, Executable>,
}

impl Runtime {
    /// Open an artifact directory (expects `manifest.json` inside).
    pub fn open(dir: impl AsRef<Path>) -> crate::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir, manifest, compiled: HashMap::new() })
    }

    /// Compile (once) and return the named artifact.
    pub fn load(&mut self, name: &str) -> crate::Result<&Executable> {
        if !self.compiled.contains_key(name) {
            let spec = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))?
                .clone();
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.compiled.insert(name.to_string(), Executable { spec, exe });
        }
        Ok(&self.compiled[name])
    }

    /// Compile and run in one call.
    pub fn run(&mut self, name: &str, inputs: &[Value]) -> crate::Result<Vec<Value>> {
        self.load(name)?;
        self.compiled[name].run(inputs)
    }

    /// Artifact names available.
    pub fn names(&self) -> Vec<&str> {
        self.manifest.artifacts.iter().map(|a| a.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT-backed tests live in rust/tests/runtime_integration.rs (they
    // need `make artifacts`). Here: pure Value conversions.

    #[test]
    fn value_accessors() {
        let t = Dense::from_vec(&[2], vec![1.0f32, 2.0]);
        let v = Value::F32(t.clone());
        assert_eq!(v.as_f32().unwrap(), &t);
        assert!(v.as_scalar_f32().is_err());
        assert_eq!(Value::ScalarF32(3.5).as_scalar_f32().unwrap(), 3.5);
        let one = Value::F32(Dense::from_vec(&[1], vec![7.0f32]));
        assert_eq!(one.as_scalar_f32().unwrap(), 7.0);
    }
}
