//! Row-major dense tensor.

use super::DType;

/// Element trait for [`Dense`].
pub trait Scalar: Copy + Default + PartialEq + Send + Sync + std::fmt::Debug + 'static {
    /// The runtime dtype tag for this element type.
    const DTYPE: DType;
}

impl Scalar for f32 {
    const DTYPE: DType = DType::F32;
}
impl Scalar for i8 {
    const DTYPE: DType = DType::I8;
}
impl Scalar for i32 {
    const DTYPE: DType = DType::I32;
}

/// A row-major dense tensor.
///
/// Rank is dynamic but almost everything in the pipeline is rank-2
/// (`[rows, cols]`): node-feature matrices `H`, weights `W`, edge-feature
/// matrices `E` (one row per edge). Rank-1 is used for per-node scalars.
#[derive(Debug, Clone, PartialEq)]
pub struct Dense<T: Scalar> {
    shape: Vec<usize>,
    data: Vec<T>,
}

impl<T: Scalar> Dense<T> {
    /// Tensor of zeros (well, `T::default()`) with the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Dense { shape: shape.to_vec(), data: vec![T::default(); n] }
    }

    /// Build from a flat row-major buffer. Panics if sizes disagree.
    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} needs {n} elements, got {}", data.len());
        Dense { shape: shape.to_vec(), data }
    }

    /// Shape as a slice.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Number of rows (first dimension). Panics on rank-0.
    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    /// Number of columns: the product of all trailing dims (1 for rank-1).
    pub fn cols(&self) -> usize {
        self.shape.iter().skip(1).product::<usize>().max(1)
    }

    /// Flat element buffer.
    pub fn data(&self) -> &[T] {
        &self.data
    }

    /// Mutable flat element buffer.
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Consume into the flat buffer.
    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Row `i` as a slice (rank>=1, row-major).
    #[inline]
    pub fn row(&self, i: usize) -> &[T] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    /// Row `i` as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [T] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    /// 2-D indexed read. Debug-asserted bounds; hot paths use `row()`.
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> T {
        debug_assert!(i < self.rows() && j < self.cols());
        self.data[i * self.cols() + j]
    }

    /// 2-D indexed write.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: T) {
        let c = self.cols();
        debug_assert!(i < self.rows() && j < c);
        self.data[i * c + j] = v;
    }

    /// Reshape in place (element count must be preserved).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {shape:?}", self.shape);
        self.shape = shape.to_vec();
        self
    }

    /// Elementwise map into a (possibly differently typed) tensor.
    pub fn map<U: Scalar>(&self, f: impl Fn(T) -> U) -> Dense<U> {
        Dense { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    /// Memory footprint of the payload in bytes.
    pub fn size_bytes(&self) -> usize {
        self.data.len() * T::DTYPE.size_bytes()
    }

    /// 2-D transpose for any element type. Panics on non-rank-2 tensors.
    pub fn transpose2d(&self) -> Dense<T> {
        assert_eq!(self.shape.len(), 2, "transpose2d needs rank-2");
        let (r, c) = (self.rows(), self.cols());
        let mut out = Dense::zeros(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
        out
    }
}

impl Dense<f32> {
    /// 2-D transpose. Only defined for rank-2 tensors.
    pub fn transpose(&self) -> Dense<f32> {
        self.transpose2d()
    }

    /// Maximum absolute value (0.0 for an empty tensor). This is the single
    /// reduction dynamic symmetric quantization needs per tensor.
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Elementwise a += b. Shapes must match.
    pub fn add_assign(&mut self, other: &Dense<f32>) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += b;
        }
    }

    /// Elementwise a -= scale * b (SGD-style update). Shapes must match.
    pub fn axpy_neg(&mut self, scale: f32, other: &Dense<f32>) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a -= scale * b;
        }
    }

    /// Scale all elements in place.
    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// Max |a - b| between two same-shaped tensors.
    pub fn max_abs_diff(&self, other: &Dense<f32>) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(other.data.iter())
            .fold(0.0f32, |m, (&a, &b)| m.max((a - b).abs()))
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|&x| x * x).sum::<f32>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t: Dense<f32> = Dense::zeros(&[3, 4]);
        assert_eq!(t.shape(), &[3, 4]);
        assert_eq!(t.len(), 12);
        assert_eq!(t.rows(), 3);
        assert_eq!(t.cols(), 4);
        assert!(t.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn from_vec_roundtrip() {
        let t = Dense::from_vec(&[2, 2], vec![1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(t.at(0, 1), 2.0);
        assert_eq!(t.at(1, 0), 3.0);
        assert_eq!(t.row(1), &[3.0, 4.0]);
    }

    #[test]
    #[should_panic]
    fn from_vec_wrong_size_panics() {
        let _ = Dense::from_vec(&[2, 2], vec![1.0f32, 2.0, 3.0]);
    }

    #[test]
    fn transpose_2d() {
        let t = Dense::from_vec(&[2, 3], vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let tt = t.transpose();
        assert_eq!(tt.shape(), &[3, 2]);
        assert_eq!(tt.at(0, 0), 1.0);
        assert_eq!(tt.at(0, 1), 4.0);
        assert_eq!(tt.at(2, 1), 6.0);
        // double transpose is identity
        assert_eq!(tt.transpose(), t);
    }

    #[test]
    fn abs_max_handles_negatives_and_empty() {
        let t = Dense::from_vec(&[4], vec![-3.0f32, 1.0, 2.5, -0.5]);
        assert_eq!(t.abs_max(), 3.0);
        let e: Dense<f32> = Dense::zeros(&[0]);
        assert_eq!(e.abs_max(), 0.0);
    }

    #[test]
    fn map_changes_dtype() {
        let t = Dense::from_vec(&[2], vec![1.4f32, -2.6]);
        let q: Dense<i8> = t.map(|x| x.round() as i8);
        assert_eq!(q.data(), &[1, -3]);
    }

    #[test]
    fn axpy_and_add() {
        let mut a = Dense::from_vec(&[2], vec![1.0f32, 2.0]);
        let b = Dense::from_vec(&[2], vec![10.0f32, 20.0]);
        a.add_assign(&b);
        assert_eq!(a.data(), &[11.0, 22.0]);
        a.axpy_neg(0.5, &b);
        assert_eq!(a.data(), &[6.0, 12.0]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Dense::from_vec(&[4], vec![1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]);
        assert_eq!(t.at(1, 1), 4.0);
    }

    #[test]
    fn size_bytes_accounts_for_dtype() {
        let f: Dense<f32> = Dense::zeros(&[8]);
        let q: Dense<i8> = Dense::zeros(&[8]);
        assert_eq!(f.size_bytes(), 32);
        assert_eq!(q.size_bytes(), 8);
    }
}
