//! Dense tensor substrate.
//!
//! A deliberately small row-major dense tensor over the three element types
//! the Tango pipeline needs: `f32` (full precision), `i8` (quantized
//! payloads) and `i32` (quantized accumulators). This is the in-memory
//! representation both the CPU primitives (`crate::primitives`) and the PJRT
//! runtime boundary (`crate::runtime`) operate on.

mod dense;

pub use dense::{Dense, Scalar};

/// Element types a [`Dense`] tensor can carry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// 32-bit IEEE float — full-precision tensors and dequantized outputs.
    F32,
    /// 8-bit signed integer — quantized payloads (INT4 values are stored in
    /// i8 slots too; sub-byte packing is modelled in `perfmodel`).
    I8,
    /// 32-bit signed integer — quantized matmul accumulators.
    I32,
}

impl DType {
    /// Size of one element in bytes.
    ///
    /// Note: INT4 payloads are *stored* in `i8` slots on the CPU substrate;
    /// the perf model accounts for the packed size instead.
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 => 1,
        }
    }
}

/// Shorthand constructors used pervasively in tests and benches.
pub mod prelude {
    pub use super::{DType, Dense, Scalar};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::F32.size_bytes(), 4);
        assert_eq!(DType::I32.size_bytes(), 4);
        assert_eq!(DType::I8.size_bytes(), 1);
    }
}
