//! `tango` — the Layer-3 launcher.
//!
//! ```text
//! tango train  [--config cfg.toml] [--model gcn|gat] [--dataset NAME]
//!              [--task nc|linkpred] [--mode fp32|tango|test1|test2|exact]
//!              [--epochs N] [--bits B] [--auto-bits] [--lr F] [--hidden N]
//!              [--seed S] [--sampler neighbor|degree|full] [--fanouts 10,10]
//!              [--batch-size N] [--sample-seed S] [--cache-nodes N]
//!              [--prefetch N] [--degree-buckets 8,64] [--bucket-bits 8,6,4]
//!              [--packed-compute] [--metrics-out m.json] [--trace true|false]
//!              [--trace-out t.json] [--flight-recorder N]
//!              [--ckpt-every N] [--ckpt-path ck.json] [--resume ck.json]
//!              [--inject-faults] [--fault-seed S] [--fault-producer-steps 3,7]
//!              [--fault-max-retries N] [--fault-backoff-ms MS]
//! tango repro  <table1|fig2|fig7|...|fig16|table2|all> [--quick]
//!              [--epochs N] [--speed-epochs N]
//! tango perf   diff A.json B.json [--threshold pct] [--json report.json]
//! tango plan                # print the derived quantization-caching plan
//! tango artifacts [--dir artifacts]   # list + smoke-run the AOT artifacts
//! tango multigpu [--config cfg.toml] [--workers K] [--epochs N]
//!                [--task nc|linkpred] [--quantize-grads]
//!                [--fanouts 10,10] [--batch-size N] [--sample-seed S]
//!                [--cache-nodes N] [--prefetch N]
//!                [--sampler neighbor|degree] [--degree-buckets 8,64]
//!                [--bucket-bits 8,6,4] [--packed-compute]
//!                [--metrics-out m.json] [--trace true|false]
//!                [--trace-out t.json] [--flight-recorder N]
//!                [--ckpt-every N] [--ckpt-path ck.json] [--resume ck.json]
//!                [--inject-faults] [--fault-seed S] [--fault-worker-steps 4]
//!                [--fault-link-steps 6,6,6] [--fault-lock-steps 2]
//!                [--fault-max-retries N] [--fault-backoff-ms MS]
//! ```
//!
//! `--ckpt-every N` (TOML `[ckpt] ckpt_every`) writes the `tango-ckpt/v1`
//! artifact to `--ckpt-path` every N global steps (mini-batch steps on
//! `train`, all-reduce rounds on `multigpu`, epochs for full-graph runs) —
//! atomically, each save replacing the last — plus a final run-complete
//! checkpoint. `--resume PATH` restores weights, optimizer state, the
//! epoch/batch cursor and the RNG stream descriptors, and continues
//! **bit-identically** to the uninterrupted run (the config fingerprint is
//! validated first, so resuming into a different run fails by name).
//!
//! `--inject-faults` (TOML `[fault] inject_faults`) arms the deterministic
//! fault harness: `--fault-producer-steps` panics the prefetch producer at
//! those global steps (restarted with bounded retries + simulated
//! exponential backoff), `--fault-worker-steps` fails a multigpu worker
//! (rebuilt from a peer and replayed), `--fault-link-steps` drops an
//! all-reduce link (retried, then degraded to skip-straggler past
//! `--fault-max-retries`), `--fault-lock-steps` poisons the shared store
//! lock (recovered via `into_inner`). Every fault is scheduled by step
//! under `--fault-seed` — never wall-clock — so recovered runs stay
//! bit-identical and the recovery ledger lands in the metrics artifact's
//! `fault` section.
//!
//! `--packed-compute` (TOML `[train] packed_compute`) flips the
//! [`PrimitiveBackend`](tango::primitives::PrimitiveBackend) seam: quantized
//! SPMM/GEMM run directly on bit-packed sub-byte payloads instead of
//! dequantizing to f32 first, and the sampled feature gather hands the model
//! still-packed [`QuantRows`](tango::sampler::QuantRows). Losses and RNG
//! streams are bit-identical either way; only the memory traffic changes.
//!
//! `--metrics-out PATH` (TOML `[metrics] out`) writes the structured
//! `tango-metrics/v1` JSON run artifact after the run: per-epoch stage
//! breakdown (`sample/gather/wait/compute/comm/eval/wall`), the span tree,
//! per-primitive latency histograms with `p50/p95/p99`, counters, gauges
//! and the cache/policy reports. `--trace false` (TOML `[metrics]
//! trace = false`, env `TANGO_TRACE=0`) turns the tracing layer into a true
//! no-op — losses and RNG streams are bit-identical either way.
//!
//! `--trace-out PATH` (TOML `[metrics] trace_out`) additionally records the
//! event *timeline* — per-thread `B/E/i/C` events on a run-relative clock —
//! and writes it as Chrome trace-event JSON (`tango-trace/v1`, loadable in
//! Perfetto): the producer-thread `stage1` spans visibly overlap the
//! consumer's `compute`. `--flight-recorder N` (TOML `[metrics]
//! flight_recorder`) arms the fault flight recorder: every fault-harness
//! recovery (and a trainer error return) dumps the last N timeline events
//! per thread to `<metrics-out stem>.flight.json` — a post-mortem whose
//! final events name the recovery path taken, counted in the artifact's
//! `fault.flight_dumps`. Event collection stays a single relaxed atomic
//! check when neither flag is set, so untraced runs are bit-identical.
//!
//! `tango perf diff A.json B.json` compares two `tango-metrics/v1` (or
//! `tango-bench/*`) artifacts span-by-span and counter-by-counter in
//! deterministic key order, prints a delta table and exits non-zero when a
//! gated (count-like) key moved more than `--threshold` percent (default
//! 10; timing keys are reported but never gate — CI machines jitter).
//! `--json report.json` writes the machine-readable `tango-perf/v1`
//! report; CI runs this as the blocking `perf-gate` job against a
//! committed baseline.
//!
//! `--degree-buckets`/`--bucket-bits` (TOML `[policy]`) configure the
//! degree-aware mixed-precision policy for the sampled feature gather:
//! ascending in-degree boundaries partition the nodes (bucket 0 hottest),
//! and the width list — hottest bucket first — keeps high-degree nodes at
//! high precision while compressing the cold tail below INT8. `--sampler
//! degree` additionally weights fanout draws by global in-degree. Left
//! unset, the uniform policy is bit-identical to previous behaviour.
//!
//! `--prefetch N` is the paper's §4.2 overlap: a producer thread runs
//! neighbor sampling + the quantized feature gather up to `N` batches
//! ahead of the training step (default 2; `--prefetch 0` = strictly
//! sequential, bit-identical losses either way). In `multigpu` mode every
//! worker runs its own prefetch pipeline and the per-epoch report shows
//! the measured stage-one `wait` time the overlap failed to hide.
//!
//! Models implement the `GnnModel` trait and run one unified block path
//! (a full-graph epoch is the block path over identity blocks); the
//! `--task` flag picks the `TaskHead` — softmax-CE node classification
//! (default, reports accuracy) or dot-product link prediction with
//! edge-seeded blocks and seed-edge exclusion (reports AUC). Omitted, the
//! task follows the dataset (DBLP/Amazon are LP, the rest NC).
//!
//! `multigpu` shares the sampler knobs and `--task` with `train` (same
//! flags, same `[train]` TOML keys); its own knobs live under `[multigpu]`.

use tango::config::{parse_mode, ModelKind, TrainConfig};
use tango::coordinator::{detect_reuse, CompGraph, Trainer};
use tango::metrics::fmt_time;
use tango::multigpu::{run_data_parallel, MultiGpuConfig};
use tango::repro::{self, ReproConfig};
use tango::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "train" => cmd_train(&args),
        "repro" => cmd_repro(&args),
        "plan" => cmd_plan(),
        "artifacts" => cmd_artifacts(&args),
        "multigpu" => cmd_multigpu(&args),
        "perf" => cmd_perf(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "tango — quantized GNN training (SC'23 reproduction)\n\n\
         subcommands:\n\
         \x20 train      train a GCN/GAT with Tango or baseline modes\n\
         \x20            (--sampler neighbor|degree for sampled mini-batches,\n\
         \x20            --task nc|linkpred to pick the task head,\n\
         \x20            --degree-buckets/--bucket-bits for the degree-aware\n\
         \x20            mixed-precision gather policy)\n\
         \x20 repro      regenerate a paper table/figure (or 'all')\n\
         \x20 plan       print the quantization-caching plan for a GAT layer\n\
         \x20 artifacts  list and smoke-run the AOT artifacts\n\
         \x20 multigpu   run the data-parallel simulation on sampled\n\
         \x20            mini-batches (shares --fanouts/--batch-size/\n\
         \x20            --sample-seed/--cache-nodes/--prefetch with train)\n\
         \x20 perf       diff two metrics/bench artifacts as a regression\n\
         \x20            gate (tango perf diff A.json B.json --threshold 10)\n"
    );
}

/// Print the active degree-aware policy banner, if any (shared by `train`
/// and `multigpu` so the two commands describe the same knobs identically).
fn print_policy_config(policy: &tango::config::PolicyConfig, mode_bits: u8) {
    if !policy.is_uniform() {
        println!(
            "policy: degree buckets {:?}, bucket bits {:?} (hottest first)",
            policy.degree_buckets,
            policy.effective_bits(mode_bits)
        );
    }
}

/// Print the per-bucket gather summary of a mixed-policy run (shared by
/// `train` and `multigpu`).
fn print_policy_report(policy: Option<&tango::policy::PolicyGatherReport>) {
    if let Some(policy) = policy {
        if policy.is_mixed() {
            for line in policy.summary_lines() {
                println!("{line}");
            }
        }
    }
}

/// Apply a run's `[metrics]` knobs before training starts: honour an
/// explicit `--trace` override, clear the process-global registry *and*
/// event rings so the artifacts describe this run alone, switch timeline
/// collection on iff `--trace-out` / `--flight-recorder` asked for it, and
/// arm the flight recorder (shared by `train` and `multigpu`).
fn apply_metrics_config(metrics: &tango::config::MetricsConfig) {
    if let Some(on) = metrics.trace {
        tango::obs::set_enabled(on);
    }
    tango::obs::reset();
    tango::obs::set_trace_enabled(metrics.trace_out.is_some() || metrics.flight_recorder > 0);
    if metrics.flight_recorder > 0 {
        tango::obs::set_flight_recorder(Some(&flight_path(metrics)), metrics.flight_recorder);
    } else {
        tango::obs::set_flight_recorder(None, 0);
    }
}

/// Where flight-recorder dumps land: beside the metrics artifact
/// (`<out stem>.flight.json`), else beside the trace, else `tango.flight.json`.
fn flight_path(metrics: &tango::config::MetricsConfig) -> String {
    let base = metrics.out.as_deref().or(metrics.trace_out.as_deref()).unwrap_or("tango.json");
    let stem = base.strip_suffix(".json").unwrap_or(base);
    format!("{stem}.flight.json")
}

/// Post-mortem hook for a trainer error return: mark the timeline and dump
/// the flight recorder (if armed) before the error propagates to `main`.
fn dump_on_error<T>(result: tango::Result<T>) -> tango::Result<T> {
    if result.is_err() {
        tango::obs::instant(tango::obs::keys::EVT_TRAINER_ERROR);
        if tango::obs::flight_dump(tango::obs::keys::EVT_TRAINER_ERROR) {
            tango::obs::counter_add(tango::obs::keys::CTR_FAULT_FLIGHT_DUMPS, 1);
        }
    }
    result
}

/// Read the `--config` file, if given (shared by `train` and `multigpu` so
/// the TOML is read and parsed once per run).
fn config_text(args: &Args) -> tango::Result<Option<String>> {
    match args.flags.get("config") {
        Some(path) => Ok(Some(std::fs::read_to_string(path)?)),
        None => Ok(None),
    }
}

/// Parse a `--flag` override through the binary's `Result` exit path, so a
/// malformed value prints one clear error instead of a panic backtrace.
fn flag<T: std::str::FromStr>(args: &Args, key: &str, default: T) -> tango::Result<T>
where
    T::Err: std::fmt::Debug,
{
    args.try_get_as(key, default).map_err(|e| anyhow::anyhow!(e))
}

fn train_config_from(args: &Args) -> tango::Result<TrainConfig> {
    train_config_with_toml(args, config_text(args)?.as_deref())
}

/// Build the train config from an already-read TOML text (or defaults),
/// then apply the CLI flag overrides.
fn train_config_with_toml(args: &Args, toml: Option<&str>) -> tango::Result<TrainConfig> {
    let mut cfg = match toml {
        Some(text) => TrainConfig::from_toml(text).map_err(|e| anyhow::anyhow!(e))?,
        None => TrainConfig::default(),
    };
    if let Some(m) = args.flags.get("model") {
        cfg.model = m.parse::<ModelKind>().map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(d) = args.flags.get("dataset") {
        cfg.dataset = d.clone();
    }
    cfg.epochs = flag(args, "epochs", cfg.epochs)?;
    cfg.lr = flag(args, "lr", cfg.lr)?;
    cfg.hidden = flag(args, "hidden", cfg.hidden)?;
    cfg.heads = flag(args, "heads", cfg.heads)?;
    cfg.layers = flag(args, "layers", cfg.layers)?;
    cfg.seed = flag(args, "seed", cfg.seed)?;
    let bits: u8 = flag(args, "bits", cfg.mode.bits)?;
    if let Some(m) = args.flags.get("mode") {
        cfg.mode = parse_mode(m, bits).map_err(|e| anyhow::anyhow!(e))?;
    } else {
        cfg.mode.bits = bits;
    }
    if args.get_bool("auto-bits") {
        cfg.auto_bits = true;
    }
    if let Some(s) = args.flags.get("sampler") {
        tango::config::parse_sampler(s)
            .map_err(|e| anyhow::anyhow!(e))?
            .apply(&mut cfg.sampler);
    }
    if let Some(t) = args.flags.get("task") {
        cfg.task = Some(tango::config::parse_task(t).map_err(|e| anyhow::anyhow!(e))?);
    }
    if let Some(f) = args.flags.get("fanouts") {
        cfg.sampler.fanouts = tango::config::parse_fanouts(f).map_err(|e| anyhow::anyhow!(e))?;
    }
    cfg.sampler.batch_size = flag(args, "batch-size", cfg.sampler.batch_size)?;
    cfg.sampler.seed = flag(args, "sample-seed", cfg.sampler.seed)?;
    cfg.sampler.cache_nodes = flag(args, "cache-nodes", cfg.sampler.cache_nodes)?;
    if args.flags.contains_key("cache-nodes") && cfg.sampler.cache_nodes == 0 {
        anyhow::bail!("--cache-nodes must be >= 1 (omit the flag for an unbounded cache)");
    }
    cfg.sampler.prefetch = flag(args, "prefetch", cfg.sampler.prefetch)?;
    if let Some(s) = args.flags.get("degree-buckets") {
        cfg.policy.degree_buckets =
            tango::config::parse_degree_buckets(s).map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(s) = args.flags.get("bucket-bits") {
        cfg.policy.bucket_bits =
            tango::config::parse_bucket_bits(s).map_err(|e| anyhow::anyhow!(e))?;
    }
    if args.get_bool("packed-compute") {
        cfg.packed_compute = true;
    }
    if let Some(t) = args.flags.get("trace") {
        cfg.metrics.trace =
            Some(tango::config::parse_bool(t, "--trace").map_err(|e| anyhow::anyhow!(e))?);
    }
    if let Some(p) = args.flags.get("metrics-out") {
        cfg.metrics.out = Some(p.clone());
    }
    if let Some(p) = args.flags.get("trace-out") {
        cfg.metrics.trace_out = Some(p.clone());
    }
    cfg.metrics.flight_recorder = flag(args, "flight-recorder", cfg.metrics.flight_recorder)?;
    cfg.ckpt.every = flag(args, "ckpt-every", cfg.ckpt.every)?;
    if let Some(p) = args.flags.get("ckpt-path") {
        cfg.ckpt.path = p.clone();
    }
    if let Some(p) = args.flags.get("resume") {
        cfg.ckpt.resume = Some(p.clone());
    }
    if args.get_bool("inject-faults") {
        cfg.fault.inject = true;
    }
    cfg.fault.seed = flag(args, "fault-seed", cfg.fault.seed)?;
    cfg.fault.max_retries = flag(args, "fault-max-retries", cfg.fault.max_retries)?;
    cfg.fault.backoff_ms = flag(args, "fault-backoff-ms", cfg.fault.backoff_ms)?;
    if let Some(s) = args.flags.get("fault-producer-steps") {
        cfg.fault.producer_steps =
            tango::config::parse_fault_steps(s).map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(s) = args.flags.get("fault-worker-steps") {
        cfg.fault.worker_steps =
            tango::config::parse_fault_steps(s).map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(s) = args.flags.get("fault-link-steps") {
        cfg.fault.link_steps =
            tango::config::parse_fault_steps(s).map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(s) = args.flags.get("fault-lock-steps") {
        cfg.fault.lock_steps =
            tango::config::parse_fault_steps(s).map_err(|e| anyhow::anyhow!(e))?;
    }
    cfg.log_every = flag(args, "log-every", 10)?;
    // Reject degenerate knob combinations (e.g. `--batch-size 0`) with an
    // actionable message instead of panicking mid-run.
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> tango::Result<()> {
    let cfg = train_config_from(args)?;
    println!(
        "training {:?} on {} — mode {} ({} bits), {} epochs",
        cfg.model,
        cfg.dataset,
        tango::config::mode_name(&cfg.mode),
        cfg.mode.bits,
        cfg.epochs
    );
    if cfg.sampler.enabled {
        println!(
            "sampler: {}, fanouts {:?}, batch size {}, prefetch {}",
            if cfg.sampler.degree_biased { "degree-biased" } else { "neighbor" },
            cfg.sampler.fanouts,
            cfg.sampler.batch_size,
            cfg.sampler.prefetch
        );
    }
    print_policy_config(&cfg.policy, cfg.mode.bits);
    if cfg.packed_compute {
        println!("backend: packed sub-byte kernels (--packed-compute)");
    }
    apply_metrics_config(&cfg.metrics);
    let mut trainer = Trainer::from_config(&cfg)?;
    let task = trainer.task();
    println!(
        "task: {} ({})",
        tango::config::task_name(task),
        match task {
            tango::graph::datasets::Task::NodeClassification => "softmax-CE, eval = accuracy",
            tango::graph::datasets::Task::LinkPrediction => "dot-product decoder, eval = AUC",
        }
    );
    let report = dump_on_error(trainer.run())?;
    println!(
        "\nfinal {} {:.4} | {} epochs in {} ({}/epoch) | bits {}",
        tango::config::metric_name(task),
        report.final_eval,
        report.losses.len(),
        fmt_time(report.wall_secs),
        fmt_time(report.wall_secs / report.losses.len().max(1) as f64),
        report.bits,
    );
    if cfg.sampler.enabled {
        println!(
            "stage-one wait (sampling+gather not hidden by prefetch): {} \
             ({:.0}% of train wall)",
            fmt_time(report.prefetch_wait_s),
            report.prefetch_wait_s / report.wall_secs.max(1e-12) * 100.0
        );
    }
    let totals = report.stage_totals();
    println!(
        "stage budget: wait {} + compute {} + eval {} = {} of wall {}{}",
        fmt_time(totals.wait_s),
        fmt_time(totals.compute_s),
        fmt_time(totals.eval_s),
        fmt_time(totals.accounted()),
        fmt_time(totals.wall_s),
        if cfg.sampler.enabled {
            format!(
                " | producer-side (overlapped): sample {} + gather {}",
                fmt_time(totals.sample_s),
                fmt_time(totals.gather_s)
            )
        } else {
            String::new()
        }
    );
    if let Some(stats) = report.cache {
        println!("feature cache: {}", stats.summary(report.cache_bytes));
    }
    print_policy_report(report.policy.as_ref());
    if let Some(path) = cfg.metrics.out.as_deref() {
        let artifact = tango::obs::train_artifact(&cfg, &report, &tango::obs::snapshot());
        tango::obs::write_artifact(path, &artifact)?;
        println!("metrics artifact: {path}");
    }
    if let Some(path) = cfg.metrics.trace_out.as_deref() {
        tango::obs::write_trace(path, "train")?;
        println!("trace artifact: {path}");
    }
    Ok(())
}

fn cmd_repro(args: &Args) -> tango::Result<()> {
    let id = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let cfg = ReproConfig {
        epochs: flag(args, "epochs", 30)?,
        speed_epochs: flag(args, "speed-epochs", 5)?,
        seed: flag(args, "seed", 42)?,
        quick: args.get_bool("quick"),
    };
    for table in repro::run(id, &cfg)? {
        table.print();
    }
    Ok(())
}

fn cmd_plan() -> tango::Result<()> {
    let (graph, _) = CompGraph::gat_layer_example();
    let plan = detect_reuse(&graph);
    println!("quantization-caching plan for one GAT layer (fwd+bwd):\n");
    println!("multi-consumer tensors (quantize once, share):");
    for t in &plan.multi_consumer {
        println!("  - {}", graph.tensor_name(*t));
    }
    println!("forward-quantized tensors reused by backward:");
    for t in &plan.forward_to_backward {
        println!("  - {}", graph.tensor_name(*t));
    }
    println!(
        "\nquantization passes: naive {} -> cached {} (saves {})",
        plan.naive_quantizations,
        plan.cached_quantizations,
        plan.saved()
    );
    Ok(())
}

fn cmd_artifacts(args: &Args) -> tango::Result<()> {
    let dir = args.get("dir", "artifacts");
    let mut rt = tango::runtime::Runtime::open(dir)?;
    println!("artifacts in {dir}:");
    let names: Vec<String> = rt.names().iter().map(|s| s.to_string()).collect();
    for name in &names {
        let spec = rt
            .manifest
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name} listed but missing from manifest"))?
            .clone();
        println!(
            "  {:<22} {} inputs, {} outputs — {}",
            spec.name,
            spec.inputs.len(),
            spec.num_outputs,
            spec.description
        );
    }
    // Smoke-run the quantize artifact (smallest).
    let spec = rt
        .manifest
        .get("quantize8")
        .ok_or_else(|| anyhow::anyhow!("manifest in {dir} has no quantize8 artifact"))?
        .clone();
    let shape = spec.inputs[0].shape.clone();
    let x = tango::graph::generators::random_features(shape[0], shape[1], 7);
    let out = rt.run("quantize8", &[tango::runtime::Value::F32(x)])?;
    println!("\nsmoke-run quantize8: {} outputs OK", out.len());
    Ok(())
}

fn cmd_multigpu(args: &Args) -> tango::Result<()> {
    // The sampler knobs (--fanouts/--batch-size/--sample-seed/--cache-nodes
    // and the [train] TOML keys) are the unified ones `tango train` reads.
    let toml = config_text(args)?;
    let train = train_config_with_toml(args, toml.as_deref())?;
    let data = tango::graph::datasets::load_by_name_checked(&train.dataset, train.seed)
        .map_err(|e| anyhow::anyhow!(e))?;
    let mut cfg = MultiGpuConfig::new(train);
    if let Some(text) = &toml {
        cfg.apply_toml(text).map_err(|e| anyhow::anyhow!(e))?;
    }
    cfg.workers = flag(args, "workers", cfg.workers)?;
    cfg.epochs = flag(args, "epochs", cfg.epochs)?;
    // A `[multigpu] prefetch` key overrides `[train]`'s — but the CLI flag
    // wins over both (same precedence as --workers/--epochs above).
    cfg.train.sampler.prefetch = flag(args, "prefetch", cfg.train.sampler.prefetch)?;
    if args.get_bool("quantize-grads") {
        cfg.quantize_grads = true;
    }
    if args.get_bool("no-overlap") {
        // Same treatment as the retired `overlap_quantization` TOML key:
        // fail loudly rather than silently running a different config.
        anyhow::bail!(
            "--no-overlap is gone — the overlap is a real per-worker prefetch pipeline \
             now; use --prefetch 0 for the sequential baseline"
        );
    }
    let task = tango::config::TaskKind::resolve(cfg.train.task, data.task);
    println!(
        "multigpu: {} workers, task {}, {} sampler, fanouts {:?}, batch size {}, \
         {} payloads, prefetch {}",
        cfg.workers,
        tango::config::task_name(task),
        if cfg.train.sampler.degree_biased { "degree-biased" } else { "uniform" },
        cfg.train.sampler.fanouts,
        cfg.train.sampler.batch_size,
        if cfg.quantize_grads { "quantized" } else { "fp32" },
        cfg.train.sampler.prefetch
    );
    print_policy_config(&cfg.train.policy, cfg.train.mode.bits);
    if cfg.train.packed_compute {
        println!("backend: packed sub-byte kernels (--packed-compute)");
    }
    apply_metrics_config(&cfg.train.metrics);
    let report = dump_on_error(run_data_parallel(&cfg, &data))?;
    for (i, e) in report.epochs.iter().enumerate() {
        println!(
            "epoch {i}: {} steps, compute {} + comm {} + wait {} = {}  (loss {:.4}; \
             producer sample {} / gather {})",
            e.steps,
            fmt_time(e.compute_s),
            fmt_time(e.comm_s),
            fmt_time(e.wait_s),
            fmt_time(e.total()),
            e.loss,
            fmt_time(e.sample_s),
            fmt_time(e.gather_s)
        );
    }
    println!("total modelled wall time: {}", fmt_time(report.total_time()));
    if let Some(stats) = report.cache {
        println!("shared feature cache: {}", stats.summary(report.cache_bytes));
    }
    print_policy_report(report.policy.as_ref());
    if let Some(path) = cfg.train.metrics.out.as_deref() {
        let artifact = tango::obs::multigpu_artifact(&cfg, &report, &tango::obs::snapshot());
        tango::obs::write_artifact(path, &artifact)?;
        println!("metrics artifact: {path}");
    }
    if let Some(path) = cfg.train.metrics.trace_out.as_deref() {
        tango::obs::write_trace(path, "multigpu")?;
        println!("trace artifact: {path}");
    }
    Ok(())
}

const PERF_USAGE: &str = "usage: tango perf diff A.json B.json [--threshold pct] [--json out.json]";

fn cmd_perf(args: &Args) -> tango::Result<()> {
    if args.positional.get(1).map(|s| s.as_str()) != Some("diff") {
        anyhow::bail!("{PERF_USAGE}");
    }
    let (Some(a), Some(b)) = (args.positional.get(2), args.positional.get(3)) else {
        anyhow::bail!("{PERF_USAGE}");
    };
    let threshold: f64 = flag(args, "threshold", 10.0)?;
    let report = tango::perf::diff_files(a, b, threshold)?;
    for line in report.table_lines() {
        println!("{line}");
    }
    if let Some(path) = args.flags.get("json") {
        tango::util::fsio::write_atomic(path, &report.to_json().to_string())?;
        println!("perf report: {path}");
    }
    if report.regressions > 0 {
        anyhow::bail!(
            "{} perf regression(s) beyond the {:.1}% threshold",
            report.regressions,
            threshold
        );
    }
    println!("perf: OK — {} keys compared, threshold {:.1}%", report.rows.len(), threshold);
    Ok(())
}
